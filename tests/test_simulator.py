"""Cycle-level simulator: calibration against the paper's Table III/IV,
staggered-vs-equal scheduling (Fig. 10), sparsity benefits (Fig. 19/Table IV),
stall trends (Fig. 16), and the dataflow energy ranking (Fig. 15)."""
import math

import pytest

from repro.core import energy as E
from repro.core.dataflow import ALL_DATAFLOWS, analyze_dataflow, compare_dataflows, dataflow_name
from repro.core.scheduler import EncoderSpec, build_encoder_ops, priority_key, topo_check
from repro.core.simulator import Simulator


def run_edge(**kw):
    sim = Simulator(E.ACCELTRAN_EDGE)
    return sim.run_encoder(EncoderSpec.bert_tiny(), batch=4, **kw)


class TestCalibration:
    def test_server_bert_tiny_table_iv(self):
        """Paper Table IV row 1: 172,180 seq/s, 0.1396 mJ/seq, 24.04 W."""
        sim = Simulator(E.ACCELTRAN_SERVER)
        res = sim.run_encoder(EncoderSpec.bert_tiny(), batch=32, weight_density=0.5, act_density=0.5)
        assert abs(res.throughput_seq_s - 172_180) / 172_180 < 0.05
        assert abs(res.energy_per_seq_j * 1e3 - 0.1396) / 0.1396 < 0.08
        assert abs(res.avg_power_w - 24.04) / 24.04 < 0.08

    def test_edge_power_envelope(self):
        """Fig. 17 / Table III: AccelTran-Edge ~6.8 W total."""
        res = run_edge(weight_density=0.5, act_density=0.5)
        assert 4.0 < res.avg_power_w < 9.0

    def test_ablation_no_dynatran_slower(self):
        """Table IV: w/o DynaTran 93,333 seq/s (vs 172,180) — dense activations."""
        sim = Simulator(E.ACCELTRAN_SERVER)
        dense = sim.run_encoder(EncoderSpec.bert_tiny(), batch=32, weight_density=0.5, act_density=1.0)
        sparse = sim.run_encoder(EncoderSpec.bert_tiny(), batch=32, weight_density=0.5, act_density=0.5)
        ratio = sparse.throughput_seq_s / dense.throughput_seq_s
        assert 1.5 < ratio < 2.2  # paper: 172180/93333 = 1.84

    def test_ablation_no_sparsity_modules(self):
        """Table IV: w/o sparsity-aware modules throughput drops ~1.9x and
        energy roughly doubles."""
        base = Simulator(E.ACCELTRAN_SERVER)
        off = Simulator(E.ACCELTRAN_SERVER, sparsity_modules=False)
        r1 = base.run_encoder(EncoderSpec.bert_tiny(), batch=32, weight_density=0.5, act_density=0.5)
        r2 = off.run_encoder(EncoderSpec.bert_tiny(), batch=32, weight_density=0.5, act_density=0.5)
        assert r1.throughput_seq_s > 1.4 * r2.throughput_seq_s
        assert r2.energy_per_seq_j > 1.4 * r1.energy_per_seq_j

    def test_lp_mode_power_reduction(self):
        """Table III: LP mode ~39% lower power at ~39% lower throughput."""
        full = Simulator(E.ACCELTRAN_EDGE).run_encoder(EncoderSpec.bert_tiny(), batch=4, weight_density=0.5, act_density=0.5)
        lp = Simulator(E.edge_lp_mode()).run_encoder(EncoderSpec.bert_tiny(), batch=4, weight_density=0.5, act_density=0.5)
        assert lp.avg_power_w < full.avg_power_w
        assert lp.throughput_seq_s < full.throughput_seq_s

    def test_rram_vs_dram(self):
        """Table IV: server on LP-DDR3 instead of mono-3D RRAM is 1.94x
        slower (172,180 vs 88,736 seq/s) — with embedding streaming, which is
        what makes the DRAM configuration memory-bound."""
        import dataclasses

        dram_cfg = dataclasses.replace(
            E.ACCELTRAN_SERVER, name="server-dram", mem_bandwidth_gbps=25.6, mem_kind="lpddr3"
        )
        rram = Simulator(E.ACCELTRAN_SERVER).run_encoder(
            EncoderSpec.bert_tiny(), batch=32, weight_density=0.5, act_density=0.5, embedding_resident=False
        )
        dram = Simulator(dram_cfg).run_encoder(
            EncoderSpec.bert_tiny(), batch=32, weight_density=0.5, act_density=0.5, embedding_resident=False
        )
        ratio = rram.throughput_seq_s / dram.throughput_seq_s
        assert 1.5 < ratio < 2.5  # paper: 1.94


class TestScheduling:
    def test_staggered_close_to_or_better_than_equal(self):
        """Fig. 10: staggered head scheduling overlaps MAC + softmax.  Under
        the tile-bundle dispatch model both policies keep the pools busy and
        land within 1% of each other (equal's lane-sharing approximates the
        same overlap); staggered must never lose by more than that, on both
        a resource-constrained variant and the stock config."""
        import dataclasses

        constrained = dataclasses.replace(E.ACCELTRAN_EDGE, pes=4)
        stag_c = Simulator(constrained, policy="staggered").run_encoder(
            EncoderSpec.bert_tiny(), batch=4, weight_density=0.5, act_density=0.5
        )
        eq_c = Simulator(constrained, policy="equal").run_encoder(
            EncoderSpec.bert_tiny(), batch=4, weight_density=0.5, act_density=0.5
        )
        assert stag_c.cycles <= eq_c.cycles * 1.01
        stag = Simulator(E.ACCELTRAN_EDGE, policy="staggered").run_encoder(
            EncoderSpec.bert_tiny(), batch=4, weight_density=0.5, act_density=0.5
        )
        eq = Simulator(E.ACCELTRAN_EDGE, policy="equal").run_encoder(
            EncoderSpec.bert_tiny(), batch=4, weight_density=0.5, act_density=0.5
        )
        assert stag.cycles <= eq.cycles * 1.01

    def test_staggered_overlaps_mac_and_softmax(self):
        """Fig. 10(b): the staggered schedule has instants where MAC lanes
        and softmax modules are busy simultaneously."""
        res = Simulator(E.ACCELTRAN_EDGE, policy="staggered").run_encoder(
            EncoderSpec.bert_tiny(), batch=4, weight_density=0.5, act_density=0.5
        )
        assert any(m > 0 and s > 0 for _, m, s, _, _ in res.util_trace)

    def test_priority_key_orders(self):
        ops = build_encoder_ops(EncoderSpec.bert_tiny(), 4)
        topo_check(ops)
        h0 = [o for o in ops if o.layer == 0 and o.head == 0]
        h1 = [o for o in ops if o.layer == 0 and o.head == 1]
        assert priority_key(h0[0], "staggered") < priority_key(h1[0], "staggered")
        # equal policy: same stage across heads sorts adjacent
        assert priority_key(h0[0], "equal")[:2] == priority_key(h1[0], "equal")[:2]

    def test_bad_policy_raises(self):
        ops = build_encoder_ops(EncoderSpec.bert_tiny(), 1)
        with pytest.raises(ValueError):
            priority_key(ops[0], "bogus")


class TestSparsityEffects:
    def test_throughput_monotone_in_sparsity(self):
        """Fig. 19: higher activation sparsity -> higher throughput, lower energy."""
        results = [run_edge(weight_density=0.5, act_density=d) for d in (1.0, 0.7, 0.5, 0.3)]
        thr = [r.throughput_seq_s for r in results]
        en = [r.energy_per_seq_j for r in results]
        assert thr == sorted(thr)
        assert en == sorted(en, reverse=True)

    def test_mac_skip_accounting(self):
        res = run_edge(weight_density=0.5, act_density=0.5)
        assert 0.5 < res.mac_skip_fraction < 0.9  # ~1 - 0.25 compounded

    def test_utilization_trace_nonempty(self):
        res = run_edge()
        assert len(res.util_trace) > 10
        t, mac, smx, ln, buf = zip(*res.util_trace)
        assert list(t) == sorted(t)
        assert max(mac) > 0 and max(smx) > 0


class TestStalls:
    def test_fewer_pes_more_compute_stalls(self):
        """Fig. 16 trend: fewer PEs -> more compute stalls."""
        import dataclasses

        small = dataclasses.replace(E.ACCELTRAN_EDGE, pes=16)
        big = dataclasses.replace(E.ACCELTRAN_EDGE, pes=128)
        r_small = Simulator(small).run_encoder(EncoderSpec.bert_tiny(), batch=4)
        r_big = Simulator(big).run_encoder(EncoderSpec.bert_tiny(), batch=4)
        assert r_small.compute_stalls >= r_big.compute_stalls

    def test_smaller_buffers_more_memory_pressure(self):
        import dataclasses

        tiny_buf = dataclasses.replace(
            E.ACCELTRAN_EDGE, act_buffer_mb=0.5, weight_buffer_mb=1.0, mask_buffer_mb=0.125
        )
        r_tiny = Simulator(tiny_buf).run_encoder(EncoderSpec.bert_base(), batch=1)
        r_big = Simulator(E.ACCELTRAN_EDGE).run_encoder(EncoderSpec.bert_base(), batch=1)
        assert r_tiny.memory_stalls >= r_big.memory_stalls


class TestDataflows:
    """Fig. 15 reproduction."""

    W = (4, 64, 64)
    A = (4, 64, 64)

    def test_paper_winners(self):
        ranked = compare_dataflows(self.W, self.A, lanes=4)
        best_names = {s.name for s in ranked if s.dynamic_energy_nj <= ranked[0].dynamic_energy_nj * (1 + 1e-9)}
        assert "[b,i,j,k]" in best_names and "[k,i,j,b]" in best_names

    def test_all_24_dataflows(self):
        assert len(ALL_DATAFLOWS) == 24
        stats = [analyze_dataflow(o, self.W, self.A) for o in ALL_DATAFLOWS]
        assert len({s.name for s in stats}) == 24
        # same MACs regardless of order
        assert len({s.macs for s in stats}) == 1

    def test_reuse_energy_anticorrelated(self):
        ranked = compare_dataflows(self.W, self.A, lanes=4)
        assert ranked[0].reuse_instances >= ranked[-1].reuse_instances

    def test_name_format(self):
        assert dataflow_name(("b", "i", "j", "k")) == "[b,i,j,k]"

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            analyze_dataflow(("b", "i", "j", "k"), (4, 64, 64), (2, 64, 64))


class TestOpGraph:
    def test_table_i_ops_present(self):
        spec = EncoderSpec.bert_tiny()
        ops = build_encoder_ops(spec, 1)
        names = {o.name for o in ops}
        # per layer/head: q/k/v/qk/softmax/sv/o; per layer: ln1, ffn1, ffn2, ln2
        assert "L0.h0.q_proj" in names and "L1.h1.softmax" in names
        assert "L0.ffn1" in names and "L1.ln2" in names
        n_mac = sum(1 for o in ops if o.kind == "mac")
        n_smx = sum(1 for o in ops if o.kind == "softmax")
        assert n_smx == spec.layers * spec.heads
        assert n_mac == 1 + spec.layers * (6 * spec.heads + 2)

    def test_macs_match_analytic(self):
        spec = EncoderSpec.bert_tiny()
        b, s, h, n, f = 4, spec.seq_len, spec.hidden, spec.heads, spec.ffn
        ops = build_encoder_ops(spec, b)
        total = sum(o.macs for o in ops)
        hd = h // n
        per_layer = n * (3 * b * s * hd * h + 2 * b * s * s * hd + b * s * hd * hd)
        per_layer += 2 * b * s * h * f
        analytic = spec.layers * per_layer + b * s * h  # + embed add
        assert total == analytic

    def test_deps_are_topological(self):
        ops = build_encoder_ops(EncoderSpec.bert_mini(), 2)
        topo_check(ops)  # raises on violation
