"""Tensor-parallel serving over a device mesh: the paged pools, gather/
scatter, and attention shard along the KV-head dim via shard_map while the
host-side scheduler stays global — and TP>1 decode is BITWISE-identical to
the single-device engine for every page kind.

Runs on an emulated mesh: XLA_FLAGS=--xla_force_host_platform_device_count=8.
With fewer than 2 visible devices the mesh tests skip — unless
REQUIRE_MULTIDEVICE is set (the CI multidevice lane), where missing devices
must FAIL, not skip: the lane exists to prove these tests ran.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_serve_mesh
from repro.models import zoo
from repro.serve.engine import ContinuousServeConfig, ContinuousServeEngine
from repro.serve.sampling import SamplingParams

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 2 and not os.environ.get("REQUIRE_MULTIDEVICE"),
    reason="needs >= 2 devices (set XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

PAGE = 4


def tiny_cfg(**kw):
    base = dict(
        name="tiny-tp", family="dense", layers=2, d_model=64, heads=4, kv_heads=4,
        d_ff=128, vocab=128, remat="none",
    )
    base.update(kw)
    return ModelConfig(**base)


def make_engine(cfg, params, **kw):
    defaults = dict(slots=2, max_len=64, page_size=PAGE, prefill_chunk=4)
    defaults.update(kw)
    return ContinuousServeEngine(cfg, params, ContinuousServeConfig(**defaults))


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    params = zoo.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=9).tolist() for _ in range(4)]
    return cfg, params, prompts


@needs_mesh
class TestServeMesh:
    def test_make_serve_mesh_shape(self):
        mesh = make_serve_mesh(2)
        assert mesh.shape == {"data": 1, "model": 2}

    def test_too_many_shards_rejected(self):
        with pytest.raises(ValueError, match="devices"):
            make_serve_mesh(len(jax.devices()) + 1)

    def test_indivisible_heads_rejected(self, setup):
        cfg = tiny_cfg(heads=3, kv_heads=3)
        params = zoo.init_params(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="divisible"):
            make_engine(cfg, params, tp=2)


@needs_mesh
class TestTPBitwise:
    """TP>1 must emit exactly the single-device engine's tokens: the pools
    shard per KV head, attention is exact per head, and the all_gather
    reassembling attention outputs is pure data movement."""

    def test_full_pages(self, setup):
        cfg, params, prompts = setup
        want = make_engine(cfg, params).generate(prompts, max_new_tokens=8)
        got = make_engine(cfg, params, tp=2).generate(prompts, max_new_tokens=8)
        assert got == want

    def test_ring_pages(self, setup):
        _, _, prompts = setup
        cfg = tiny_cfg(name="tiny-tp-ring", attention_pattern=("sliding", "full"), window=8)
        params = zoo.init_params(jax.random.PRNGKey(1), cfg)
        want = make_engine(cfg, params).generate(prompts, max_new_tokens=8)
        got = make_engine(cfg, params, tp=2).generate(prompts, max_new_tokens=8)
        assert got == want

    def test_int8_pages(self, setup):
        _, _, prompts = setup
        cfg = dataclasses.replace(tiny_cfg(), name="tiny-tp-int8", kv_cache_dtype="int8")
        params = zoo.init_params(jax.random.PRNGKey(2), cfg)
        want = make_engine(cfg, params).generate(prompts, max_new_tokens=8)
        got = make_engine(cfg, params, tp=2).generate(prompts, max_new_tokens=8)
        assert got == want

    def test_int8_ring_pages(self, setup):
        """int8 + ring combined: quantised scale pools shard on their Hkv
        dim alongside the q pools, ring addressing included."""
        _, _, prompts = setup
        cfg = tiny_cfg(
            name="tiny-tp-int8-ring", attention_pattern=("sliding", "full"), window=8,
            kv_cache_dtype="int8",
        )
        params = zoo.init_params(jax.random.PRNGKey(4), cfg)
        want = make_engine(cfg, params).generate(prompts, max_new_tokens=8)
        got = make_engine(cfg, params, tp=2).generate(prompts, max_new_tokens=8)
        assert got == want

    def test_hybrid_ssm_side_state(self, setup):
        """Hybrid models: the SSM side-state is computed replicated (every
        shard holds the identical recurrent state) while attention shards."""
        _, _, _ = setup
        cfg = ModelConfig(
            name="tiny-tp-hybrid", family="hybrid", layers=2, d_model=64, heads=4,
            kv_heads=4, d_ff=128, vocab=128, remat="none",
            attention_pattern=("sliding",), window=8,
            ssm_state=8, ssm_expand=2, ssm_conv=4,
        )
        params = zoo.init_params(jax.random.PRNGKey(3), cfg)
        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, cfg.vocab, size=6).tolist() for _ in range(3)]
        want = make_engine(cfg, params).generate(prompts, max_new_tokens=6)
        got = make_engine(cfg, params, tp=2).generate(prompts, max_new_tokens=6)
        assert got == want

    @pytest.mark.skipif(len(jax.devices()) < 4 and not os.environ.get("REQUIRE_MULTIDEVICE"),
                        reason="needs >= 4 devices")
    def test_tp4(self, setup):
        cfg, params, prompts = setup
        want = make_engine(cfg, params).generate(prompts, max_new_tokens=8)
        got = make_engine(cfg, params, tp=4).generate(prompts, max_new_tokens=8)
        assert got == want

    def test_sampled_decode_window(self, setup):
        """Per-request sampling knobs stay runtime tensors under the mesh
        (keyed streams reproduce), and multi-step decode windows scan
        through the shard_map unchanged."""
        cfg, params, prompts = setup
        sp = SamplingParams(temperature=0.8, top_k=20, seed=7, max_new_tokens=8)
        want = make_engine(cfg, params, decode_window=3).generate(prompts, sampling=sp)
        got = make_engine(cfg, params, decode_window=3, tp=2).generate(prompts, sampling=sp)
        assert got == want

    def test_runtime_taus_no_recompile_under_mesh(self, setup):
        """DynaTran taus enter the sharded step as runtime scalars: changing
        rho between calls must not retrace the TP decode step."""
        from repro.core.dynatran import SparsityConfig

        _, _, prompts = setup
        cfg = dataclasses.replace(
            tiny_cfg(), sparsity=SparsityConfig(mode="dynatran", target_rho=0.2)
        )
        params = zoo.init_params(jax.random.PRNGKey(0), cfg)
        eng = make_engine(cfg, params, tp=2, prefix_caching=False)
        eng.generate([prompts[0]], max_new_tokens=4)
        traces = eng._decode._cache_size()
        eng._fixed_rho = 0.6  # runtime knob only — no retrace allowed
        eng.generate([prompts[1]], max_new_tokens=4)
        assert eng._decode._cache_size() == traces

    def test_use_pallas_under_mesh(self, setup):
        """The fused Pallas kernel is shard-local over KV heads: the TP
        engine runs it inside shard_map (interpret mode on CPU) and matches
        the single-device Pallas engine."""
        cfg, params, prompts = setup
        want = make_engine(cfg, params, use_pallas=True).generate(prompts, max_new_tokens=6)
        got = make_engine(cfg, params, use_pallas=True, tp=2).generate(prompts, max_new_tokens=6)
        assert got == want


@needs_mesh
class TestTPMemoryAndState:
    def test_pool_bytes_split_exactly(self, setup):
        cfg, params, _ = setup
        for tp in (1, 2):
            eng = make_engine(cfg, params, tp=tp)
            m = eng.metrics()
            assert m["tp"] == tp
            assert m["cache_bytes_per_shard"] * tp == m["cache_bytes"]

    def test_int8_scale_pools_split_too(self, setup):
        cfg = dataclasses.replace(tiny_cfg(), kv_cache_dtype="int8")
        params = zoo.init_params(jax.random.PRNGKey(0), cfg)
        eng = make_engine(cfg, params, tp=2)
        assert eng.pools.shard_bytes() * 2 == eng.pools.bytes()

    def test_host_side_state_is_global(self, setup):
        """The allocator, page tables, and prefix cache never see the mesh:
        page ids are shard-invariant."""
        cfg, params, prompts = setup
        eng1 = make_engine(cfg, params)
        eng2 = make_engine(cfg, params, tp=2)
        for e in (eng1, eng2):
            e.generate(prompts[:2], max_new_tokens=6)
        a1, a2 = eng1.allocators["full"], eng2.allocators["full"]
        assert a1.num_pages == a2.num_pages
        assert a1.free_pages == a2.free_pages  # identical host-side schedule

    def test_prefix_cache_and_cow_under_tp(self, setup):
        """Shared-prefix linking and copy-on-write forks run on the global
        page ids; the device-side page copy fans out to every shard."""
        cfg, params, prompts = setup
        prompt = prompts[0][:8]  # exactly 2 pages
        ref = make_engine(cfg, params, slots=1, prefix_caching=False)
        want = ref.generate([prompt] * 2, max_new_tokens=6)
        eng = make_engine(cfg, params, slots=1, tp=2)
        a = eng.generate([prompt], max_new_tokens=6)[0]
        b = eng.generate([prompt], max_new_tokens=6)[0]
        assert [a, b] == want
        stats = eng.metrics()["prefix_cache"]
        assert stats["hits"] == 1 and stats["pages_shared"] == 2


class TestShardBytesUnsharded:
    def test_equals_total_on_one_device(self, setup):
        cfg, params, _ = setup
        eng = make_engine(cfg, params)
        assert eng.pools.shard_bytes() == eng.pools.bytes()


class TestRegressionGateLogic:
    """Unit checks on benchmarks/check_regression.py (the CI bench gate):
    parity flags fail with zero tolerance, throughput ratios gate at the
    tolerance, and a single-device run whose TP section legitimately
    skipped is not punished for the baseline's TP metrics."""

    def fresh(self, **over):
        result = {
            "analysis_clean": True,
            "bitwise_identical_rho0": True,
            "outputs_match_baseline": True,
            "speedup": 2.0,
            "baseline": {"tok_per_s": 100.0},
            "continuous": {"tok_per_s": 200.0},
            "ring": {"bitwise_identical_rho0": True, "ring_bytes_flat_in_max_len": True,
                     "tok_per_s": 150.0},
            "prefix_cache": {"tokens_identical_to_uncached": True,
                             "allocator_drained_at_shutdown": True,
                             "burst_tokens_identical": True, "burst_relinked_pages": 5,
                             "tok_per_s": 150.0},
            "tp": {"skipped": "needs >= 2 devices, have 1"},
            "families": {
                "rwkv6": {"tokens_match_dense": True, "state_bytes_flat_in_max_len": True,
                          "tok_per_s": 300.0, "slot_tok_per_s": 100.0},
                "whisper": {"tokens_match_dense": True, "allocator_drained": True,
                            "tok_per_s": 80.0},
            },
            "sparsity": {
                "tile_skip_exact": True,
                "rho05_vs_rho0": 1.2,
                "pallas_visits": {"strictly_decreasing": True},
            },
            "router": {
                "router_tokens_exact": True,
                "router_drain": True,
                "slo_ladder_ordered": True,
                "affinity_hit_rate": 0.6,
                "router2_vs_single": 0.5,
            },
            "tiering": {
                "tier_restore_exact": True,
                "restore_vs_replay": 1.5,
            },
            "speculative": {
                "spec_tokens_exact": True,
                "spec_vs_nonspec": 1.3,
            },
        }
        result.update(over)
        return result

    def baseline(self):
        return {"throughput_ratios": {"speedup": 1.0, "ring_vs_slot": 1.0,
                                      "tp2_vs_slot": 0.5, "rwkv6_vs_slot": 1.0,
                                      "rho05_vs_rho0": 1.0}}

    def test_tp_skipped_fresh_run_passes(self):
        from benchmarks.check_regression import check_parity, check_throughput

        fresh = self.fresh()
        assert check_parity(fresh) == []
        failures, _ = check_throughput(fresh, self.baseline(), 0.25)
        assert failures == []  # tp2_vs_slot absent but the section skipped

    def test_missing_nonskipped_metric_fails(self):
        from benchmarks.check_regression import check_throughput

        fresh = self.fresh(tp={"scaling": [], "bitwise_identical_tp": {}})
        failures, _ = check_throughput(fresh, self.baseline(), 0.25)
        assert any("tp2_vs_slot" in f for f in failures)

    def test_parity_flip_fails(self):
        from benchmarks.check_regression import check_parity

        fresh = self.fresh(tp={"bitwise_identical_tp": {"ring": False}, "scaling": []})
        assert any("ring pages" in f for f in check_parity(fresh))

    def test_throughput_regression_fails(self):
        from benchmarks.check_regression import check_throughput

        fresh = self.fresh(speedup=0.5)
        failures, _ = check_throughput(fresh, self.baseline(), 0.25)
        assert any("speedup regressed" in f for f in failures)

    def test_family_parity_flip_fails(self):
        """The DecodeState families' correctness claims are zero-tolerance
        parity flags: a flipped rwkv6/whisper flag fails the gate."""
        from benchmarks.check_regression import check_parity

        fresh = self.fresh()
        fresh["families"]["rwkv6"]["tokens_match_dense"] = False
        assert any("rwkv6_tokens_match_dense" in f for f in check_parity(fresh))
        fresh = self.fresh()
        del fresh["families"]["whisper"]["allocator_drained"]
        assert any("whisper_drained" in f for f in check_parity(fresh))

    def test_tile_skip_parity_flip_fails(self):
        """A tile-skipped run whose tokens diverged from the masked twin is a
        zero-tolerance failure, as is a visit counter that stopped falling."""
        from benchmarks.check_regression import check_parity

        fresh = self.fresh()
        fresh["sparsity"]["tile_skip_exact"] = False
        assert any("tile_skip_exact" in f for f in check_parity(fresh))
        fresh = self.fresh()
        fresh["sparsity"]["pallas_visits"]["strictly_decreasing"] = False
        assert any("sparsity_visits_decreasing" in f for f in check_parity(fresh))

    def test_analysis_clean_flip_fails(self):
        """A bench run whose in-process reprolint pass found violations (or
        stale baseline entries) fails the gate with zero tolerance — the
        bench gate and the lint-invariants CI lane must agree."""
        from benchmarks.check_regression import check_parity

        for bad in (False, None):
            fresh = self.fresh(analysis_clean=bad)
            assert any("analysis_clean" in f for f in check_parity(fresh)), bad

    def test_rho_ratio_hard_floor(self):
        """The rho=0.5 vs rho=0 tokens/s ratio has a HARD floor of 1.0 — a
        same-run ratio, so no machine tolerance applies.  At the floor,
        below it, or missing entirely: the gate fails."""
        from benchmarks.check_regression import check_parity

        assert check_parity(self.fresh()) == []
        for bad in (0.93, 1.0, None):
            fresh = self.fresh()
            fresh["sparsity"]["rho05_vs_rho0"] = bad
            assert any("rho05_vs_rho0" in f for f in check_parity(fresh)), bad

    def test_rho_ratio_tracked_in_trajectory(self):
        from benchmarks.check_regression import throughput_ratios

        assert throughput_ratios(self.fresh())["rho05_vs_rho0"] == 1.2

    def test_router_parity_flip_fails(self):
        """The router's placement-invisibility claims are zero-tolerance:
        token divergence, lossy drain, or a shed before the rho ladder
        saturates each fails the gate — as does a flag missing entirely."""
        from benchmarks.check_regression import check_parity

        for key, label in (
            ("router_tokens_exact", "router_tokens_exact"),
            ("router_drain", "router_drain"),
            ("slo_ladder_ordered", "router_slo_ladder_ordered"),
        ):
            for bad in (False, None):
                fresh = self.fresh()
                if bad is None:
                    del fresh["router"][key]
                else:
                    fresh["router"][key] = bad
                assert any(label in f for f in check_parity(fresh)), (key, bad)

    def test_router_affinity_hit_rate_must_be_positive(self):
        from benchmarks.check_regression import check_parity

        fresh = self.fresh()
        fresh["router"]["affinity_hit_rate"] = 0.0
        assert any("affinity hit" in f for f in check_parity(fresh))

    def test_tier_restore_parity_flip_fails(self):
        """A restored request whose tokens diverged from the straight
        decode / evict+replay run is a zero-tolerance failure — as is the
        flag missing entirely (e.g. the tiering section silently dropped)."""
        from benchmarks.check_regression import check_parity

        for bad in (False, None):
            fresh = self.fresh()
            if bad is None:
                del fresh["tiering"]["tier_restore_exact"]
            else:
                fresh["tiering"]["tier_restore_exact"] = bad
            assert any("tier_restore_exact" in f for f in check_parity(fresh)), bad

    def test_tier_ratio_hard_floor(self):
        """The restore-vs-replay ratio has a HARD same-run floor of 1.0 —
        a tier that does not beat re-prefilling is pure overhead.  At the
        floor, below it, or missing: the gate fails; above it, the ratio
        feeds the trajectory."""
        from benchmarks.check_regression import check_parity, throughput_ratios

        assert check_parity(self.fresh()) == []
        assert throughput_ratios(self.fresh())["tier_restore_vs_replay"] == 1.5
        for bad in (0.8, 1.0, None):
            fresh = self.fresh()
            fresh["tiering"]["restore_vs_replay"] = bad
            assert any("tier_restore_vs_replay" in f for f in check_parity(fresh)), bad

    def test_spec_parity_flip_fails(self):
        """A speculative run whose emitted streams diverged from the
        non-speculative engine is a zero-tolerance failure — as is the flag
        missing entirely (e.g. the speculative section silently dropped)."""
        from benchmarks.check_regression import check_parity

        for bad in (False, None):
            fresh = self.fresh()
            if bad is None:
                del fresh["speculative"]["spec_tokens_exact"]
            else:
                fresh["speculative"]["spec_tokens_exact"] = bad
            assert any("spec_tokens_exact" in f for f in check_parity(fresh)), bad

    def test_spec_ratio_hard_floor(self):
        """The spec-vs-nonspec tokens/s ratio has a HARD same-run floor of
        1.0 — speculation that does not beat one-token-per-dispatch decode
        is pure overhead.  At the floor, below it, or missing: the gate
        fails; above it, the ratio feeds the trajectory."""
        from benchmarks.check_regression import check_parity, throughput_ratios

        assert check_parity(self.fresh()) == []
        assert throughput_ratios(self.fresh())["spec_vs_nonspec"] == 1.3
        for bad in (0.9, 1.0, None):
            fresh = self.fresh()
            fresh["speculative"]["spec_vs_nonspec"] = bad
            assert any("spec_vs_nonspec" in f for f in check_parity(fresh)), bad

    def test_router_ratio_hard_floor(self):
        """The 2-replica vs single-engine tokens/s ratio has a HARD same-run
        floor (no machine tolerance): at the floor, below it, or missing,
        the gate fails; above it, the ratio feeds the trajectory."""
        from benchmarks.check_regression import check_parity, throughput_ratios

        assert check_parity(self.fresh()) == []
        assert throughput_ratios(self.fresh())["router2_vs_single"] == 0.5
        for bad in (0.1, 0.25, None):
            fresh = self.fresh()
            fresh["router"]["router2_vs_single"] = bad
            assert any("router2_vs_single" in f for f in check_parity(fresh)), bad


@needs_mesh
class TestPallasKernelShardLocal:
    """The Pallas gather and fused decode-attention kernels, called with
    shard-local operands inside shard_map, reproduce the head-slices of the
    unsharded kernel outputs."""

    def test_paged_gather_head_sharded(self):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.kernels.paged_attention import paged_gather
        from repro.launch.sharding import SHARD_MAP_NO_CHECK, shard_map

        mesh = make_serve_mesh(2)
        rng = np.random.default_rng(0)
        pool = rng.standard_normal((6, 4, 4, 8)).astype(np.float32)
        table = np.array([[1, 2, 0], [3, 4, 5]], np.int32)
        want = paged_gather(jax.numpy.asarray(pool), jax.numpy.asarray(table))
        spec = P(None, None, "model", None)
        f = shard_map(
            lambda p, t: paged_gather(p, t), mesh=mesh,
            in_specs=(spec, P()), out_specs=P(None, None, "model", None),
            **SHARD_MAP_NO_CHECK,
        )
        pool_s = jax.device_put(jax.numpy.asarray(pool), NamedSharding(mesh, spec))
        got = f(pool_s, jax.numpy.asarray(table))
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_fused_attention_head_sharded(self):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.kernels.paged_attention import paged_decode_attention
        from repro.launch.sharding import SHARD_MAP_NO_CHECK, shard_map

        mesh = make_serve_mesh(2)
        rng = np.random.default_rng(1)
        pool_k = rng.standard_normal((6, 4, 4, 8)).astype(np.float32)
        pool_v = rng.standard_normal((6, 4, 4, 8)).astype(np.float32)
        table = np.array([[1, 2, 0], [3, 4, 5]], np.int32)
        lengths = np.array([9, 11], np.int32)
        q = rng.standard_normal((2, 1, 8, 8)).astype(np.float32)
        want = paged_decode_attention(
            jax.numpy.asarray(q), jax.numpy.asarray(pool_k), jax.numpy.asarray(pool_v),
            jax.numpy.asarray(table), jax.numpy.asarray(lengths),
        )
        pspec = P(None, None, "model", None)
        f = shard_map(
            lambda qq, kk, vv, tt, ll: paged_decode_attention(qq, kk, vv, tt, ll),
            mesh=mesh,
            in_specs=(P(None, None, "model", None), pspec, pspec, P(), P()),
            out_specs=P(None, None, "model", None),
            **SHARD_MAP_NO_CHECK,
        )
        put = lambda x, s: jax.device_put(jax.numpy.asarray(x), NamedSharding(mesh, s))
        got = f(
            put(q, P(None, None, "model", None)), put(pool_k, pspec), put(pool_v, pspec),
            jax.numpy.asarray(table), jax.numpy.asarray(lengths),
        )
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
