"""GPipe pipeline parallelism: exact equivalence with the plain forward.

Runs in a subprocess because it needs >1 XLA host device (the main pytest
process is pinned to 1)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_smoke
from repro.models import zoo
from repro.launch.pipeline import make_pipeline_forward, pipeline_param_shardings

cfg = dataclasses.replace(get_smoke("qwen3-4b"), remat="none")
mesh = jax.make_mesh((2,), ("pod",))
params = zoo.init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
ref_logits, _ = zoo.forward(params, cfg, tokens)
fwd = make_pipeline_forward(cfg, mesh, n_micro=2)
pshard = pipeline_param_shardings(cfg, jax.eval_shape(lambda: params), mesh)
params_s = jax.device_put(params, pshard)
got = jax.jit(fwd)(params_s, tokens)
err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - ref_logits.astype(jnp.float32))))
assert err < 1e-3, err
print("PIPELINE_OK", err)
"""


@pytest.mark.slow
def test_pipeline_matches_forward():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=600,
    )
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr
