"""Training loop + fault tolerance: loss decreases, checkpoint/restart is
exact, async checkpointing, watchdog straggler detection, data determinism."""
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.data.pipeline import ClsDataConfig, ClassificationBatches, LMBatches, LMDataConfig
from repro.models import zoo
from repro.optim import adamw
from repro.train.loop import Watchdog, train


def tiny_cfg(**kw):
    return ModelConfig(
        name="tiny-test", family="dense", layers=2, d_model=64, heads=2, kv_heads=2,
        d_ff=128, vocab=256, remat="none", **kw,
    )


class TestDataPipeline:
    def test_deterministic_resume(self):
        cfg = LMDataConfig(vocab=256, seq_len=32, batch=4)
        src1, src2 = LMBatches(cfg), LMBatches(cfg)
        b1 = src1.batch(7)
        b2 = src2.batch(7)  # fresh object, same (seed, step) -> same batch
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_labels_shifted(self):
        cfg = LMDataConfig(vocab=256, seq_len=16, batch=2)
        b = LMBatches(cfg).batch(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)

    def test_bigram_structure_learnable(self):
        # every (t, t+1) pair must be in the bigram table
        cfg = LMDataConfig(vocab=64, seq_len=32, batch=4, branching=4)
        src = LMBatches(cfg)
        b = src.batch(3)
        seq = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
        for row in seq:
            for t in range(len(row) - 1):
                assert row[t + 1] in src.table[row[t]]

    def test_classification_batches(self):
        cfg = ClsDataConfig(vocab=512, seq_len=16, batch=8)
        src = ClassificationBatches(cfg)
        b = src.batch(0)
        assert b["tokens"].shape == (8, 16) and set(np.unique(b["labels"])) <= {0, 1}
        ev = src.eval_set(2)
        assert len(ev) == 2


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        cfg = tiny_cfg()
        data = LMBatches(LMDataConfig(vocab=cfg.vocab, seq_len=32, batch=8, branching=2))
        ocfg = adamw.OptimizerConfig(lr=1e-2, warmup_steps=5, total_steps=60, clip_norm=1.0)
        state, hist = train(cfg, ocfg, data, steps=60, log_every=10, log=lambda s: None)
        assert hist[-1]["loss"] < hist[0]["loss"] * 0.8, [h["loss"] for h in hist]

    def test_checkpoint_resume_exact(self, tmp_path):
        cfg = tiny_cfg()
        data = LMBatches(LMDataConfig(vocab=cfg.vocab, seq_len=16, batch=4))
        ocfg = adamw.OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
        # uninterrupted run to 10
        s_full, _ = train(cfg, ocfg, data, steps=10, checkpoint_dir=d1, checkpoint_every=5, log=lambda s: None)
        # interrupted run: 5 steps, then resume to 10
        train(cfg, ocfg, data, steps=5, checkpoint_dir=d2, checkpoint_every=5, log=lambda s: None)
        s_res, _ = train(cfg, ocfg, data, steps=10, checkpoint_dir=d2, checkpoint_every=5, log=lambda s: None)
        for a, b in zip(jax.tree_util.tree_leaves(s_full.params), jax.tree_util.tree_leaves(s_res.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5, atol=1e-6)


class TestWatchdog:
    def test_trips_on_blowout(self):
        wd = Watchdog(factor=3.0, min_steps=3)
        for _ in range(10):
            assert wd.record(1.0)
        assert not wd.record(10.0)  # stalled collective / straggler
        assert wd.trips == 1

    def test_tolerates_warmup(self):
        wd = Watchdog(factor=3.0, min_steps=5)
        assert wd.record(10.0)  # first step (compile) sets EMA
        assert wd.record(1.0)


class TestCheckpointStore:
    def _tree(self):
        return {
            "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b16": jnp.ones((5,), jnp.bfloat16) * 1.5, "i": jnp.array([1, 2, 3])},
        }

    def test_roundtrip_with_bf16(self, tmp_path):
        d = str(tmp_path)
        tree = self._tree()
        store.save(d, 3, tree)
        got, manifest = store.restore(d, tree)
        assert manifest["step"] == 3
        for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(got)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_latest_step(self, tmp_path):
        d = str(tmp_path)
        assert store.latest_step(d) is None
        store.save(d, 1, self._tree())
        store.save(d, 7, self._tree())
        assert store.latest_step(d) == 7

    def test_restore_missing_leaf_rejected(self, tmp_path):
        d = str(tmp_path)
        store.save(d, 1, {"w": jnp.ones(3)})
        with pytest.raises(ValueError):
            store.restore(d, {"w": jnp.ones(3), "extra": jnp.ones(2)})

    def test_restore_shape_mismatch_rejected(self, tmp_path):
        d = str(tmp_path)
        store.save(d, 1, {"w": jnp.ones((3,))})
        with pytest.raises(ValueError):
            store.restore(d, {"w": jnp.ones((4,))})

    def test_atomic_overwrite(self, tmp_path):
        d = str(tmp_path)
        store.save(d, 2, {"w": jnp.zeros(3)})
        store.save(d, 2, {"w": jnp.ones(3)})  # same step again: atomic replace
        got, _ = store.restore(d, {"w": jnp.zeros(3)})
        np.testing.assert_array_equal(np.asarray(got["w"]), 1.0)
        assert not any(n.startswith("tmp.") for n in os.listdir(d))

    def test_async_checkpointer_and_gc(self, tmp_path):
        d = str(tmp_path)
        ck = store.AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save_async(s, {"w": jnp.full((2,), float(s))})
        ck.wait()
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(d) if n.startswith("step_"))
        assert steps == [3, 4]
        got, _ = store.restore(d, {"w": jnp.zeros(2)})
        np.testing.assert_array_equal(np.asarray(got["w"]), 4.0)

    def test_async_error_surfaced(self, tmp_path):
        ck = store.AsyncCheckpointer(str(tmp_path / "nope" / "\0bad"))
        ck.save_async(1, {"w": jnp.zeros(2)})
        with pytest.raises(BaseException):
            ck.wait()

    def test_elastic_restore_with_shardings(self, tmp_path):
        # restore onto an explicit (degenerate) mesh sharding — the rescale path
        from jax.sharding import NamedSharding, PartitionSpec as P

        d = str(tmp_path)
        tree = {"w": jnp.arange(8, dtype=jnp.float32)}
        store.save(d, 1, tree)
        mesh = jax.make_mesh((1,), ("data",))
        shardings = {"w": NamedSharding(mesh, P("data"))}
        got, _ = store.restore(d, tree, shardings=shardings)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8))
        assert got["w"].sharding == shardings["w"]


class TestOptimizer:
    def test_converges_quadratic(self):
        cfg = adamw.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0, clip_norm=0)
        params = {"x": jnp.array([5.0, -3.0])}
        state = adamw.init_state(params, cfg)
        for _ in range(150):
            grads = {"x": 2 * params["x"]}
            params, state, _ = adamw.apply_updates(params, grads, state, cfg)
        assert float(jnp.abs(params["x"]).max()) < 0.3

    def test_clip_norm(self):
        cfg = adamw.OptimizerConfig(clip_norm=1.0)
        params = {"x": jnp.zeros(4)}
        state = adamw.init_state(params, cfg)
        _, _, m = adamw.apply_updates(params, {"x": jnp.full(4, 100.0)}, state, cfg)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_schedule_shape(self):
        cfg = adamw.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
        assert lrs[1] == pytest.approx(0.5)  # mid-warmup
        assert lrs[2] == pytest.approx(1.0)  # peak
        assert lrs[-1] == pytest.approx(0.1)  # floor
        assert lrs[3] < lrs[2]

    def test_no_decay_on_1d(self):
        cfg = adamw.OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=1.0, clip_norm=0)
        params = {"scale": jnp.ones(4), "w": jnp.ones((4, 4))}
        state = adamw.init_state(params, cfg)
        p2, _, _ = adamw.apply_updates(params, {"scale": jnp.zeros(4), "w": jnp.zeros((4, 4))}, state, cfg)
        np.testing.assert_array_equal(np.asarray(p2["scale"]), 1.0)  # zero grad + no decay
        assert float(p2["w"][0, 0]) < 1.0  # decayed

    def test_bf16_compression_close(self):
        cfg = adamw.OptimizerConfig(grad_compression="bf16", clip_norm=0, warmup_steps=0)
        params = {"x": jnp.zeros(16)}
        state = adamw.init_state(params, cfg)
        g = jnp.linspace(-1, 1, 16)
        p1, _, _ = adamw.apply_updates(params, {"x": g}, state, cfg)
        cfg2 = adamw.OptimizerConfig(grad_compression="none", clip_norm=0, warmup_steps=0)
        p2, _, _ = adamw.apply_updates(params, {"x": g}, adamw.init_state(params, cfg2), cfg2)
        np.testing.assert_allclose(np.asarray(p1["x"]), np.asarray(p2["x"]), rtol=0.05, atol=1e-5)

    def test_int8_error_feedback_state(self):
        cfg = adamw.OptimizerConfig(grad_compression="int8_ef", clip_norm=0, warmup_steps=0)
        params = {"x": jnp.zeros(8)}
        state = adamw.init_state(params, cfg)
        assert "ef" in state
        g = jnp.linspace(-1, 1, 8)
        _, state2, _ = adamw.apply_updates(params, {"x": g}, state, cfg)
        assert "ef" in state2
        # residual is bounded by one quantisation step
        assert float(jnp.abs(state2["ef"]["x"]).max()) <= float(jnp.abs(g).max()) / 127.0 + 1e-6
