"""Continuous-batching scheduler: admission order, no starvation, rho
controller monotonicity, engine equivalence with the dense baseline."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import zoo
from repro.models.kvcache import PageAllocator
from repro.serve.engine import ContinuousServeConfig, ContinuousServeEngine, ServeConfig, ServeEngine
from repro.serve.scheduler import ContinuousScheduler, Request, RhoController, summarize


def make_req(rid, prompt_len=8, max_new=8):
    return Request(rid=rid, prompt=list(range(1, prompt_len + 1)), max_new_tokens=max_new)


def make_sched(slots=2, num_pages=17, page_size=4, maxp=4):
    return ContinuousScheduler(
        slots, {"full": PageAllocator(num_pages, page_size)}, {"full": maxp}, maxp * page_size
    )


def make_ring_sched(slots=2, num_pages=9, page_size=4, budget=3, max_len=64):
    return ContinuousScheduler(
        slots, {"ring": PageAllocator(num_pages, page_size)}, {"ring": budget}, max_len
    )


class TestAdmission:
    def test_fifo_order(self):
        s = make_sched(slots=2)
        reqs = [make_req(i) for i in range(4)]
        for r in reqs:
            s.submit(r)
        admitted = s.admit_ready()
        assert [r.rid for r in admitted] == [0, 1]  # head-of-queue first
        assert [r.rid for r in s.queue] == [2, 3]

    def test_admission_blocks_on_pages_not_just_slots(self):
        s = make_sched(slots=4, num_pages=5)  # 4 usable pages
        for i in range(3):
            s.submit(make_req(i, prompt_len=8))  # needs 3 pages each (8+1 tokens / 4)
        admitted = s.admit_ready()
        assert len(admitted) == 1  # second request cannot fit its replay
        assert s.queue_depth == 2

    def test_oversized_request_rejected(self):
        s = make_sched(maxp=2, page_size=4)
        with pytest.raises(ValueError):
            s.submit(make_req(0, prompt_len=8, max_new=8))  # 16 > 2*4


class TestEviction:
    def test_youngest_evicted_and_requeued_at_front(self):
        s = make_sched(slots=2, num_pages=7)
        old, young = make_req(0, prompt_len=8), make_req(1, prompt_len=8)
        s.submit(old)
        s.submit(young)
        assert len(s.admit_ready()) == 2
        old.cache_len = 12  # old needs a 4th page; pool is empty -> evict young
        assert s.grow(old) is True
        assert young.slot is None and s.queue[0] is young
        assert old.slot is not None

    def test_oldest_never_evicted(self):
        s = make_sched(slots=2, num_pages=7)
        old, young = make_req(0), make_req(1)
        s.submit(old)
        s.submit(young)
        s.admit_ready()
        young.cache_len = 12
        assert s.grow(young) is False  # young evicts itself, never the oldest
        assert old.slot is not None
        assert young.slot is None

    def test_grow_never_reserves_past_request_budget(self):
        # prompt 8 + max_new 24 = 32 tokens = 2 pages of 16; a decode window
        # larger than the remaining budget must not demand a third page
        s = ContinuousScheduler(1, {"full": PageAllocator(3, 16)}, {"full": 4}, 64)
        req = make_req(0, prompt_len=8, max_new=24)
        s.submit(req)
        s.admit_ready()
        req.cache_len = 24
        assert s.grow(req, new_tokens=16) is True  # capped at budget 32 -> 2 pages
        assert len(s.allocators["full"].owned(req.rid)) == 2

    def test_no_starvation_under_churn(self):
        """With continuous arrivals and page pressure, the oldest queued
        request is always the next admitted — arrival order is preserved."""
        s = make_sched(slots=2, num_pages=9)
        done_order = []
        for r in (make_req(0), make_req(1)):
            s.submit(r)
        rid = 2
        for step in range(200):
            s.admit_ready()
            for req in list(s.active.values()):
                req.cache_len += 1
                if req.cache_len >= len(req.prompt) + 4:
                    s.finish(req)
                    done_order.append(req.rid)
            for req in list(s.active.values()):
                s.grow(req)
            if rid < 8 and step % 3 == 0:
                s.submit(make_req(rid))
                rid += 1
            if not s.queue and not s.active:
                break
        assert done_order == sorted(done_order)  # FIFO completion, nobody starved


class TestRingRecycling:
    def test_ring_pages_capped_at_budget_under_growth(self):
        s = make_ring_sched(slots=1, num_pages=5, budget=3, page_size=4)
        req = make_req(0, prompt_len=8, max_new=40)  # 48 tokens, 12 intervals
        s.submit(req)
        s.admit_ready()
        alloc = s.allocators["ring"]
        assert len(alloc.owned(req.rid)) == 3  # replay+1 = 9 tokens -> 3 intervals
        for cache_len in range(9, 48):
            req.cache_len = cache_len
            assert s.grow(req, 1) is True
            owned = alloc.owned(req.rid)
            assert len(owned) <= 3  # never exceeds ceil(window-span/P) + 1
            assert len(req.tables["ring"]) == 3  # table stays fully linked
            assert alloc.free_pages + len(owned) == 4  # conservation

    def test_ring_admission_allocates_at_most_budget(self):
        # a long replay still only needs the ring budget, so a pool sized
        # for the window admits arbitrarily long prompts
        s = make_ring_sched(slots=1, num_pages=4, budget=3, page_size=4, max_len=256)
        req = make_req(0, prompt_len=200, max_new=8)
        s.submit(req)  # would need 52 pages append-only; ring needs 3
        assert len(s.admit_ready()) == 1
        assert len(s.allocators["ring"].owned(req.rid)) == 3

    def test_ring_recycle_interleaves_with_other_sequences(self):
        s = make_ring_sched(slots=2, num_pages=7, budget=3, page_size=4)
        a, b = make_req(0, prompt_len=4, max_new=40), make_req(1, prompt_len=4, max_new=40)
        s.submit(a)
        s.submit(b)
        assert len(s.admit_ready()) == 2
        alloc = s.allocators["ring"]
        for step in range(5, 40):
            for req in (a, b):
                req.cache_len = step
                assert s.grow(req, 1) is True
            owned_a, owned_b = set(alloc.owned(a.rid)), set(alloc.owned(b.rid))
            assert not owned_a & owned_b  # no page double-owned, ever
            assert len(owned_a) <= 3 and len(owned_b) <= 3
            assert alloc.free_pages + len(owned_a) + len(owned_b) == 6

    def test_mixed_kinds_admission_rolls_back_on_failure(self):
        # ring reservation succeeds first, then the full pool runs dry: the
        # partial ring reservation must be rolled back for the blocked head
        s = ContinuousScheduler(
            2,
            {"ring": PageAllocator(9, 4), "full": PageAllocator(5, 4)},
            {"ring": 3, "full": 8},
            32,
        )
        r0, r1 = make_req(0), make_req(1)  # replay+1 = 9 -> 3 full + 3 ring pages
        s.submit(r0)
        s.submit(r1)
        admitted = s.admit_ready()
        assert [r.rid for r in admitted] == [0]  # only 1 full page left for r1
        assert r1.tables == {} and r1.ring_hi == 0  # fully rolled back
        assert s.allocators["ring"].free_pages == 8 - 3  # only r0's pages held
        assert s.allocators["full"].free_pages == 4 - 3


class TestRhoController:
    def test_monotone_in_queue_depth(self):
        rhos = [RhoController(0.0, 0.6, 1, 16, ema=1.0).update(d) for d in range(0, 40)]
        assert all(b >= a for a, b in zip(rhos, rhos[1:]))
        assert rhos[0] == 0.0 and abs(rhos[-1] - 0.6) < 1e-9

    def test_bounded(self):
        c = RhoController(0.1, 0.5, 1, 8, ema=0.7)
        for d in (0, 3, 100, 0, 50, 2):
            rho = c.update(d)
            assert 0.1 <= rho <= 0.5

    def test_relaxes_when_drained(self):
        c = RhoController(0.0, 0.6, 1, 4, ema=0.5)
        for _ in range(10):
            high = c.update(32)
        for _ in range(20):
            low = c.update(0)
        assert high > 0.5 and low < 0.01

    def test_invalid_bounds_rejected(self):
        with pytest.raises(ValueError):
            RhoController(0.5, 0.2)


class TestContinuousEngine:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = ModelConfig(
            name="tiny-cont",
            family="dense",
            layers=2,
            d_model=64,
            heads=2,
            kv_heads=2,
            d_ff=128,
            vocab=128,
            remat="none",
        )
        params = zoo.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab, size=10).tolist() for _ in range(5)]
        return cfg, params, prompts

    def test_matches_dense_baseline(self, setup):
        cfg, params, prompts = setup
        base = ServeEngine(cfg, params, ServeConfig(slots=1, max_len=64))
        want = [base.generate([p], max_new_tokens=6)[0] for p in prompts]
        eng = ContinuousServeEngine(
            cfg, params, ContinuousServeConfig(slots=3, max_len=64, page_size=4, prefill_chunk=1)
        )
        assert eng.generate(prompts, max_new_tokens=6) == want

    def test_decode_window_matches_single_step(self, setup):
        cfg, params, prompts = setup
        one = ContinuousServeEngine(
            cfg, params, ContinuousServeConfig(slots=2, max_len=64, page_size=4, prefill_chunk=4)
        )
        want = one.generate(prompts, max_new_tokens=7)
        win = ContinuousServeEngine(
            cfg,
            params,
            ContinuousServeConfig(slots=2, max_len=64, page_size=4, prefill_chunk=4, decode_window=3),
        )
        assert win.generate(prompts, max_new_tokens=7) == want

    def test_eos_stops_early(self, setup):
        cfg, params, prompts = setup
        eng = ContinuousServeEngine(
            cfg, params, ContinuousServeConfig(slots=2, max_len=64, page_size=4, prefill_chunk=4)
        )
        full = eng.generate([prompts[0]], max_new_tokens=8)[0]
        eos = full[2]
        eng2 = ContinuousServeEngine(
            cfg, params, ContinuousServeConfig(slots=2, max_len=64, page_size=4, prefill_chunk=4)
        )
        got = eng2.generate([prompts[0]], max_new_tokens=8, eos_id=eos)[0]
        assert got[-1] == eos and len(got) <= 8

    def test_slo_and_latency_metrics(self, setup):
        cfg, params, prompts = setup
        eng = ContinuousServeEngine(
            cfg, params, ContinuousServeConfig(slots=2, max_len=64, page_size=4, prefill_chunk=4)
        )
        eng.submit(prompts[0], max_new_tokens=4, slo_s=1000.0)
        eng.submit(prompts[1], max_new_tokens=4, slo_s=1e-9)
        eng.run_until_complete()
        m = summarize(eng.requests)
        assert m["finished"] == 2 and m["tokens"] == 8
        assert m["p50_latency_s"] > 0 and m["p99_latency_s"] >= m["p50_latency_s"]
        assert m["slo_met_frac"] == 0.5
        assert all(r.ttft() is not None for r in eng.requests)

    def test_adaptive_rho_rises_under_load_and_relaxes(self, setup):
        import dataclasses

        from repro.core.dynatran import SparsityConfig

        cfg, params, prompts = setup
        cfg2 = dataclasses.replace(cfg, sparsity=SparsityConfig(mode="dynatran", target_rho=0.0))
        eng = ContinuousServeEngine(
            cfg2,
            params,
            ContinuousServeConfig(
                slots=2,
                max_len=64,
                page_size=4,
                prefill_chunk=4,
                adaptive_rho=True,
                rho_max=0.5,
                depth_lo=1,
                depth_hi=4,
            ),
        )
        for p in prompts * 2:
            eng.submit(p, max_new_tokens=4)
        peak = 0.0
        while eng.sched.queue or eng.sched.active:
            eng.step()
            peak = max(peak, eng.current_rho)
        assert peak > 0.3  # deep queue pushed rho up
        assert eng.current_rho < peak  # drained queue relaxed it
