"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Artifacts land in experiments/bench/<name>.json.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    bench_ablation,
    bench_accuracy_sparsity,
    bench_comparison,
    bench_dataflows,
    bench_hardware,
    bench_prune_throughput,
    bench_roofline,
    bench_serve_continuous,
    bench_sparsity_effect,
    bench_stalls,
    bench_utilization,
)

BENCHES = {
    "accuracy_sparsity": bench_accuracy_sparsity.run,  # Figs. 11/12/14
    "prune_throughput": bench_prune_throughput.run,  # Fig. 13
    "dataflows": bench_dataflows.run,  # Fig. 15
    "stalls": bench_stalls.run,  # Fig. 16
    "utilization": bench_utilization.run,  # Fig. 17
    "hardware": bench_hardware.run,  # Table III / Fig. 18
    "sparsity_effect": bench_sparsity_effect.run,  # Fig. 19
    "comparison": bench_comparison.run,  # Fig. 20
    "ablation": bench_ablation.run,  # Table IV
    "roofline": bench_roofline.run,  # §Roofline (from dry-run artifacts)
    "serve_continuous": bench_serve_continuous.run,  # paged-KV continuous batching
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced repeats/steps")
    ap.add_argument("--only", default=None, help="run one benchmark by name")
    args = ap.parse_args()

    names = [args.only] if args.only else list(BENCHES)
    failures = []
    for name in names:
        t0 = time.time()
        try:
            BENCHES[name](quick=args.quick)
            print(f"[run] {name} done in {time.time()-t0:.1f}s")
        except Exception:
            failures.append(name)
            print(f"[run] {name} FAILED:\n{traceback.format_exc()}")
    if failures:
        print(f"[run] FAILURES: {failures}")
        sys.exit(1)
    print(f"[run] all {len(names)} benchmarks passed")


if __name__ == "__main__":
    main()
