"""Paper Fig. 17: power + compute/buffer utilization trace of BERT-Tiny on
AccelTran-Edge during one batch."""
from __future__ import annotations

from repro.core import energy as E
from repro.core.scheduler import EncoderSpec
from repro.core.simulator import Simulator

from .common import banner, save


def run(quick: bool = False) -> dict:
    banner("Fig. 17: BERT-Tiny on AccelTran-Edge utilization trace")
    res = Simulator(E.ACCELTRAN_EDGE).run_encoder(
        EncoderSpec.bert_tiny(), batch=4, weight_density=0.5, act_density=0.5, embedding_resident=False
    )
    trace = [
        {"cycle": t, "mac": mac, "softmax": smx, "layernorm": ln, "act_buffer": buf}
        for t, mac, smx, ln, buf in res.util_trace
    ]
    overlap = sum(1 for s in trace if s["mac"] > 0 and s["softmax"] > 0) / max(len(trace), 1)
    payload = {
        "cycles": res.cycles,
        "avg_power_w": res.avg_power_w,
        "leakage_w": res.leakage_energy_j / res.seconds,
        "mac_softmax_overlap_fraction": overlap,
        "peak_mac_util": max(s["mac"] for s in trace),
        "peak_softmax_util": max(s["softmax"] for s in trace),
        "trace_len": len(trace),
        "trace": trace if not quick else trace[:50],
    }
    print(
        f"  cycles={res.cycles:.0f} power={res.avg_power_w:.2f}W "
        f"overlap={overlap:.2f} peak_mac={payload['peak_mac_util']:.2f} "
        f"peak_smx={payload['peak_softmax_util']:.2f}"
    )
    save("utilization", payload)
    return payload


if __name__ == "__main__":
    run()
