"""Shared helpers for the benchmark harness (one module per paper artifact)."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def save(name: str, payload: dict) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timeit(fn: Callable, *args, repeat: int = 5, warmup: int = 2, **kw) -> float:
    """Median wall-clock seconds per call (after jit warmup)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def banner(title: str) -> None:
    print(f"\n=== {title} " + "=" * max(1, 70 - len(title)))
