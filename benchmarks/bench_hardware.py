"""Paper Table III + Fig. 18: area, peak TOP/s, power breakdown for
AccelTran-Edge / -Server / Edge-LP, and the compute-module area/power split."""
from __future__ import annotations

from repro.core import energy as E
from repro.core.scheduler import EncoderSpec
from repro.core.simulator import Simulator

from .common import banner, save


def run(quick: bool = False) -> dict:
    banner("Table III / Fig. 18: hardware summary")
    rows = {}
    # Table III power envelopes: Server runs BERT-Base (its design workload);
    # Edge/Edge-LP run BERT-Tiny (Fig. 17's workload).
    for cfg, spec, batch in [
        (E.ACCELTRAN_SERVER, EncoderSpec.bert_base(), 32),
        (E.ACCELTRAN_EDGE, EncoderSpec.bert_tiny(), 4),
        (E.edge_lp_mode(), EncoderSpec.bert_tiny(), 4),
    ]:
        res = Simulator(cfg).run_encoder(spec, batch=batch, weight_density=0.5, act_density=0.5)
        rows[cfg.name] = {
            "area_mm2": cfg.area_mm2,
            "peak_tops": cfg.peak_tops,
            "paper_total_power_w": cfg.total_power_w,
            "simulated_power_w": res.avg_power_w,
            "throughput_seq_s": res.throughput_seq_s,
            "energy_per_seq_mj": res.energy_per_seq_j * 1e3,
        }
        print(
            f"  {cfg.name:22s} area={cfg.area_mm2:8.2f}mm2 peak={cfg.peak_tops:7.2f}TOP/s "
            f"P_paper={cfg.total_power_w:6.2f}W P_sim={res.avg_power_w:6.2f}W"
        )
    payload = {
        "note": "Table III total power is the all-modules-active envelope; "
                "simulated power is the workload average (see EXPERIMENTS.md "
                "calibration note on the Tiny/Base energy inconsistency)",
        "table_iii": rows,
        "fig18_area_breakdown": E.AREA_BREAKDOWN_EDGE,
        "fig18_power_breakdown": E.POWER_BREAKDOWN_EDGE,
    }
    save("hardware", payload)
    return payload


if __name__ == "__main__":
    run()
