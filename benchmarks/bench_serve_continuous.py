"""Continuous batching vs slot-granularity serving at a skewed request mix.

The slot-granularity `ServeEngine` runs every admitted row for the wave's
longest request, so a few long generations strand short rows as padding.
The paged-KV `ContinuousServeEngine` frees a row the step its request
finishes and admits queued work immediately, so useful-token throughput
tracks occupancy instead of the wave maximum.

Measures tokens/s and p50/p99 request latency for both engines on a
75%-short / 25%-long mix, and verifies the paged decode path is
bitwise-identical to the dense-KV baseline at target_rho=0.

The prefix section measures refcounted shared-prefix page caching on a
shared-system-prompt workload: identical tokens to the uncached run,
cache hit rate > 0, fewer pages in use than the no-sharing baseline, and
a fully drained allocator at shutdown — all asserted.  A cold same-tick
burst additionally pins the vLLM-style incremental registration: identical
prompts submitted together dedupe INSIDE one admission wave (pages relink
mid-prefill), holding fewer pages at prefill completion than the uncached
run while emitting identical tokens.

The TP section shards the engine over an emulated device mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``): tensor-parallel
decode must be bitwise-identical to the single-device engine for every
page kind (full / ring / int8), per-shard pool bytes must equal total/N,
and tokens/s is reported per shard count.  Skipped (reported, not failed)
when only one device is visible.

The sparsity section measures the tiled DynaTran datapath (KernelPolicy
``skip``): the tile-skipping engine must emit tokens identical to its
masked-reference twin at the same taus, tokens/s must RISE with target
rho (the "sparsity pays" claim, gated as the rho=0.5 / rho=0 ratio), and
the fused Pallas decode kernel's per-row page-visit counters must fall
strictly as rho rises.

The speculative section measures speculative decoding through the paged
engine: streams must be bitwise-identical to the non-speculative engine
for every paged kind under forced eviction and with DynaTran draft
pruning live (zero-tolerance ``spec_tokens_exact``), and self-speculation
at draft_rho == rho must beat one-token-per-dispatch decode
(``spec_vs_nonspec`` ratio, hard floor 1.0 downstream).

The tiering section measures the host page tier: eviction spills KV pages
to host memory and re-admission restores them instead of replaying
prefill.  Restored tokens must be bitwise-identical to both the straight
decode and the evict+replay run for every paged kind (zero-tolerance
``tier_restore_exact``), and on a long-prompt re-admission workload the
restore path must beat replay (``restore_vs_replay`` ratio, hard floor
1.0 downstream).
"""
from __future__ import annotations

import dataclasses
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dynatran import SparsityConfig, ThresholdCalculator, TransferCurve
from repro.models import zoo
from repro.serve.engine import ContinuousServeConfig, ContinuousServeEngine, ServeConfig, ServeEngine
from repro.serve.scheduler import pct as _pct

from .common import banner, save


def _tiny_cfg() -> ModelConfig:
    # big enough that model compute dominates per-call dispatch overhead:
    # the claim under test is the serving schedule, not kernel launch cost
    return ModelConfig(
        name="bench-serve", family="dense", layers=4, d_model=256, heads=8, kv_heads=4,
        d_ff=512, vocab=512, remat="none",
    )


def _ring_cfg() -> ModelConfig:
    # gemma2-style local/global alternation: half the layers page into
    # window-budget ring tables instead of max_len-budget full tables
    return ModelConfig(
        name="bench-serve-ring", family="dense", layers=4, d_model=256, heads=8, kv_heads=4,
        d_ff=512, vocab=512, remat="none",
        attention_pattern=("sliding", "full"), window=32,
    )


def _pool_bytes_by_kind(engine) -> dict:
    """Split the engine's pool bytes into ring vs full slots."""
    out = {"ring": 0, "full": 0}
    for i, kind in enumerate(engine.layout.slot_kinds):
        for entry in (engine.pools.k[str(i)], engine.pools.v[str(i)]):
            out[kind] += sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(entry))
    return out


def _run_ring_section(quick: bool) -> dict:
    """Sliding-window (ring) paging on the continuous engine: correctness
    vs the dense baseline, throughput, and the memory claim — ring pool
    bytes scale with ``window`` while a dense cache scales with max_len."""
    from repro.models.kvcache import cache_bytes

    cfg = _ring_cfg()
    params = zoo.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    slots, window = 4, cfg.window
    n_req = 6 if quick else 16
    new_tokens = 24 if quick else 48
    requests = [(rng.integers(1, 256, size=8).tolist(), new_tokens) for _ in range(n_req)]
    useful = sum(new for _, new in requests)

    base = ServeEngine(cfg, params, ServeConfig(slots=1, max_len=128))
    want = [base.generate([p], max_new_tokens=new)[0] for p, new in requests[:3]]
    eng1 = ContinuousServeEngine(
        cfg, params, ContinuousServeConfig(slots=1, max_len=128, page_size=8, prefill_chunk=1)
    )
    got = [eng1.generate([p], max_new_tokens=new)[0] for p, new in requests[:3]]
    bitwise = want == got

    engine = ContinuousServeEngine(
        cfg, params, ContinuousServeConfig(slots=slots, max_len=128, page_size=8, prefill_chunk=8)
    )
    engine.generate([p for p, _ in requests[:slots]], max_new_tokens=2)  # jit warmup
    engine.clear_history()
    t0 = time.perf_counter()
    for p, new in requests:
        engine.submit(p, max_new_tokens=new)
    engine.run_until_complete()
    wall = time.perf_counter() - t0

    # memory scaling: ring pool bytes are flat in max_len (window-bound);
    # the dense per-slot cache and the full-attention pool both grow linearly
    scaling = []
    for max_len in (128, 256, 512):
        e = ContinuousServeEngine(
            cfg, params, ContinuousServeConfig(slots=slots, max_len=max_len, page_size=8)
        )
        kinds = _pool_bytes_by_kind(e)
        scaling.append(
            {
                "max_len": max_len,
                "ring_pool_bytes": kinds["ring"],
                "full_pool_bytes": kinds["full"],
                "dense_cache_bytes": cache_bytes(cfg.layers, slots, max_len, cfg.kv_heads, cfg.hd),
            }
        )
    flat = scaling[0]["ring_pool_bytes"] == scaling[-1]["ring_pool_bytes"]
    return {
        "bitwise_identical_rho0": bitwise,
        "tok_per_s": useful / wall,
        "window": window,
        "memory_scaling": scaling,
        "ring_bytes_flat_in_max_len": flat,
    }


def _run_tp_section(quick: bool) -> dict:
    """Tensor-parallel serving over the mesh "model" axis: bitwise parity
    with the single-device engine for every page kind, per-shard pool
    memory = total/N, and tokens/s per shard count.  CPU-emulated meshes
    exercise the whole path; real chips run the same code."""
    n_dev = len(jax.devices())
    if n_dev < 2:
        return {
            "skipped": f"needs >= 2 devices, have {n_dev} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        }
    cfg = _tiny_cfg()
    params = zoo.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    n_req = 6 if quick else 16
    new_tokens = 16 if quick else 32
    requests = [(rng.integers(1, 256, size=8).tolist(), new_tokens) for _ in range(n_req)]
    useful = sum(new for _, new in requests)

    def build(c, p, tp):
        return ContinuousServeEngine(
            c, p, ContinuousServeConfig(slots=4, max_len=128, page_size=8, prefill_chunk=8, tp=tp)
        )

    # bitwise parity at TP>1 for every page kind (greedy decode: identical
    # token streams are the engine-level bitwise claim)
    ring_cfg = _ring_cfg()
    int8_cfg = dataclasses.replace(_tiny_cfg(), name="bench-serve-int8", kv_cache_dtype="int8")
    flavours = {
        "full": (cfg, params),
        "ring": (ring_cfg, zoo.init_params(jax.random.PRNGKey(1), ring_cfg)),
        "int8": (int8_cfg, zoo.init_params(jax.random.PRNGKey(2), int8_cfg)),
    }
    tp_test = 2
    parity = {}
    for kind, (c, p) in flavours.items():
        prompts = [q for q, _ in requests[:4]]
        want = build(c, p, 1).generate(prompts, max_new_tokens=new_tokens)
        got = build(c, p, tp_test).generate(prompts, max_new_tokens=new_tokens)
        parity[kind] = want == got

    # throughput + per-shard memory per shard count.  On an emulated mesh
    # all shards run on one physical CPU, so tokens/s is a schedule sanity
    # number, not a hardware scaling claim — the asserted claims here are
    # parity and the memory split.
    scaling = []
    for tp in (1, 2, 4):
        if tp > n_dev or cfg.kv_heads % tp:
            continue
        eng = build(cfg, params, tp)
        eng.generate([q for q, _ in requests[:4]], max_new_tokens=2)  # jit warmup
        eng.clear_history()
        t0 = time.perf_counter()
        for q, new in requests:
            eng.submit(q, max_new_tokens=new)
        eng.run_until_complete()
        wall = time.perf_counter() - t0
        m = eng.metrics()
        scaling.append(
            {
                "tp": tp,
                "tok_per_s": useful / wall,
                "pool_bytes": m["cache_bytes"],
                "pool_bytes_per_shard": m["cache_bytes_per_shard"],
                "shard_bytes_exact": m["cache_bytes_per_shard"] * tp == m["cache_bytes"],
            }
        )
    return {
        "devices": n_dev,
        "bitwise_identical_tp": parity,
        "scaling": scaling,
    }


def _run_prefix_section(quick: bool) -> dict:
    """Refcounted shared-prefix page caching on a shared-system-prompt
    workload: one warm-up request fills the cache, then concurrent bursts
    link the same physical prompt pages.  Asserted claims: the cached run
    emits IDENTICAL tokens to the same workload with caching disabled, hits
    the cache, holds measurably fewer pages during the bursts, and the
    allocator drains to empty at shutdown (no leaked retention refs)."""
    cfg = _tiny_cfg()
    params = zoo.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    slots, page_size = 4, 8
    system = rng.integers(1, 256, size=32).tolist()  # 4 full pages of shared prefix
    n_req = 8 if quick else 24
    new_tokens = 8 if quick else 16
    tails = [rng.integers(1, 256, size=4).tolist() for _ in range(n_req)]
    warmup = system + rng.integers(1, 256, size=4).tolist()

    results = {}
    for caching in (False, True):
        eng = ContinuousServeEngine(
            cfg, params,
            ContinuousServeConfig(slots=slots, max_len=128, page_size=page_size,
                                  prefill_chunk=8, prefix_caching=caching),
        )
        outs = [eng.generate([warmup], max_new_tokens=new_tokens)[0]]  # fills the cache
        eng.clear_history()
        eng._peak_pages_in_use = 0  # measure the burst phase alone
        t0 = time.perf_counter()
        reqs = [eng.submit(system + tail, max_new_tokens=new_tokens) for tail in tails]
        eng.run_until_complete()
        wall = time.perf_counter() - t0
        outs += [r.generated for r in reqs]
        m = eng.metrics()
        eng.drop_prefix_cache()
        results[caching] = {
            "outs": outs,
            "wall_s": wall,
            "peak_pages_in_use": m["peak_pages_in_use"],
            "prefix_cache": m["prefix_cache"],
            "drained": all(a.free_pages == a.num_pages - 1 for a in eng.allocators.values()),
        }

    # cold same-tick burst (no warm-up): identical prompts submitted
    # together must dedupe INSIDE the admission wave — pages register as
    # each one fills and peers relink them mid-prefill (vLLM-style), so
    # the cached run holds fewer pages by the time every row is decoding
    burst = {}
    burst_tails = tails[: min(6, n_req)]
    for caching in (False, True):
        eng = ContinuousServeEngine(
            cfg, params,
            ContinuousServeConfig(slots=len(burst_tails), max_len=128, page_size=page_size,
                                  prefill_chunk=page_size, prefix_caching=caching),
        )
        reqs = [eng.submit(system + tail, max_new_tokens=new_tokens) for tail in burst_tails]
        in_use_at_ready = None
        for _ in range(100_000):
            if all(r.done for r in reqs):
                break
            eng.step()
            if in_use_at_ready is None and all(r.ready or r.done for r in reqs):
                a = eng.allocators["full"]
                in_use_at_ready = a.num_pages - 1 - a.free_pages
        else:
            raise RuntimeError("cold-burst section: step budget exhausted")
        burst[caching] = {
            "outs": [r.generated for r in reqs],
            "pages_at_ready": in_use_at_ready,
            "relinked_pages": eng.metrics()["prefix_cache"]["relinked_pages"] if caching else 0,
        }

    cached, plain = results[True], results[False]
    stats = cached["prefix_cache"]
    return {
        "requests": n_req + 1,
        "system_prompt_pages": len(system) // page_size,
        "tokens_identical_to_uncached": cached["outs"] == plain["outs"],
        "hit_rate": stats["hit_rate"],
        "pages_shared": stats["pages_shared"],
        "peak_pages_in_use": cached["peak_pages_in_use"],
        "peak_pages_in_use_no_sharing": plain["peak_pages_in_use"],
        "tok_per_s": (n_req * new_tokens) / cached["wall_s"],
        "tok_per_s_no_sharing": (n_req * new_tokens) / plain["wall_s"],
        "allocator_drained_at_shutdown": cached["drained"] and plain["drained"],
        "burst_tokens_identical": burst[True]["outs"] == burst[False]["outs"],
        "burst_relinked_pages": burst[True]["relinked_pages"],
        "burst_pages_at_ready": burst[True]["pages_at_ready"],
        "burst_pages_at_ready_no_sharing": burst[False]["pages_at_ready"],
    }


def _run_families_section(quick: bool) -> dict:
    """The DecodeState-registry families (ISSUE 5): rwkv6 decodes through
    pure slot-dense recurrent state (no pages at all — state bytes flat in
    max_len, asserted) and whisper serves with slot-dense encoder cross-KV
    plus paged decoder self-KV.  Both must emit tokens identical to their
    dense-state replay; rwkv6 tokens/s is additionally gated as a ratio vs
    the same run's slot-granularity engine."""
    from repro import configs as cfg_registry
    from repro.models import whisper as whisper_mod

    out = {}

    # --- rwkv6: slot-dense recurrent state ---------------------------------
    cfg = cfg_registry.get_smoke("rwkv6-7b")
    params = zoo.init_params(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(5)
    n_req = 6 if quick else 16
    new_tokens = 12 if quick else 24
    requests = [(rng.integers(1, cfg.vocab, size=8).tolist(), new_tokens) for _ in range(n_req)]
    useful = sum(new for _, new in requests)

    base1 = ServeEngine(cfg, params, ServeConfig(slots=1, max_len=96))
    want = [base1.generate([p], max_new_tokens=new)[0] for p, new in requests[:3]]
    eng1 = ContinuousServeEngine(
        cfg, params, ContinuousServeConfig(slots=1, max_len=96, page_size=8, prefill_chunk=1)
    )
    got = [eng1.generate([p], max_new_tokens=new)[0] for p, new in requests[:3]]
    rwkv_match = want == got

    slot_eng = ServeEngine(cfg, params, ServeConfig(slots=4, max_len=96))
    slot_eng.generate([p for p, _ in requests[:4]], max_new_tokens=2)  # jit warmup
    _, _, slot_wall = _run_baseline(slot_eng, requests, slots=4)

    eng = ContinuousServeEngine(
        cfg, params, ContinuousServeConfig(slots=4, max_len=96, page_size=8, prefill_chunk=8)
    )
    eng.generate([p for p, _ in requests[:4]], max_new_tokens=2)  # jit warmup
    eng.clear_history()
    t0 = time.perf_counter()
    for p, new in requests:
        eng.submit(p, max_new_tokens=new)
    eng.run_until_complete()
    wall = time.perf_counter() - t0

    # the O(1)-per-slot memory claim: no pages, slot bytes flat in max_len
    small = ContinuousServeEngine(cfg, params, ContinuousServeConfig(slots=4, max_len=96, page_size=8))
    large = ContinuousServeEngine(cfg, params, ContinuousServeConfig(slots=4, max_len=768, page_size=8))
    flat = small.state_bytes() == large.state_bytes() and small.state_bytes()["paged"] == 0
    out["rwkv6"] = {
        "tokens_match_dense": rwkv_match,
        "state_bytes_flat_in_max_len": flat,
        "state_bytes": small.state_bytes(),
        "tok_per_s": useful / wall,
        "slot_tok_per_s": useful / slot_wall,
    }

    # --- whisper: slot-dense cross-KV + paged self-KV ----------------------
    wcfg = cfg_registry.get_smoke("whisper-tiny")
    wparams = zoo.init_params(jax.random.PRNGKey(6), wcfg)
    wrng = np.random.default_rng(6)
    w_req = [(wrng.integers(1, wcfg.vocab, size=8).tolist(), new_tokens) for _ in range(n_req)]
    frames = [
        wrng.standard_normal((wcfg.encoder_frames, wcfg.d_model)).astype(np.float32)
        for _ in w_req
    ]

    w_want = [
        whisper_mod.dense_reference_decode(wparams, wcfg, p, f, new, 96)
        for (p, new), f in zip(w_req[:3], frames[:3])
    ]
    weng1 = ContinuousServeEngine(
        wcfg, wparams, ContinuousServeConfig(slots=1, max_len=96, page_size=8, prefill_chunk=1)
    )
    w_got = weng1.generate(
        [p for p, _ in w_req[:3]], max_new_tokens=new_tokens,
        inputs=[{"frames": f} for f in frames[:3]],
    )
    weng = ContinuousServeEngine(
        wcfg, wparams, ContinuousServeConfig(slots=4, max_len=96, page_size=8, prefill_chunk=8)
    )
    t0 = time.perf_counter()
    reqs = [weng.submit(p, max_new_tokens=new, inputs={"frames": f})
            for (p, new), f in zip(w_req, frames)]
    weng.run_until_complete()
    w_wall = time.perf_counter() - t0
    out["whisper"] = {
        "tokens_match_dense": w_got == w_want,
        "allocator_drained": all(a.free_pages == a.num_pages - 1 for a in weng.allocators.values()),
        "state_bytes": weng.state_bytes(),
        "tok_per_s": sum(new for _, new in w_req) / w_wall,
    }
    assert all(len(r.generated) == new_tokens for r in reqs)
    return out


def _sparse_cfg() -> ModelConfig:
    # attention-heavy tiny model (long KV read per decoded token, small FFN)
    # so skipped KV pages move the wall clock; "kv" occupancy is opt-in
    return ModelConfig(
        name="bench-serve-sparse", family="dense", layers=2, d_model=256, heads=8, kv_heads=8,
        d_ff=128, vocab=512, remat="none",
        sparsity=SparsityConfig(mode="dynatran", sites=("ffn_act", "attn_out", "kv"), block=16),
    )


def _profiled_calculator(eng: ContinuousServeEngine) -> ThresholdCalculator:
    """Transfer curves for the sparsity section.  The "kv" curve is profiled
    from the probe engine's own filled pools — tau at rho r is the
    r-quantile of the per-position max|k| magnitudes, so ``target_rho``
    maps onto a real dead fraction of the cache regardless of the model's
    activation scale.  The activation sites get modest linear ramps."""
    mags = []
    for i in range(len(eng.layout.slot_kinds)):
        pool = np.asarray(jax.tree_util.tree_leaves(eng.pools.k[str(i)])[0])
        # pool is [n_cycles, num_pages, P, Hkv, D]: per-position max|k| is the
        # max over the trailing (Hkv, D) axes — the occupancy_bit reduction
        m = np.abs(pool).max(axis=(-2, -1)).ravel()
        mags.append(m[m > 0])  # unwritten pool slots are exactly zero
    mags = np.concatenate(mags)
    rhos = np.linspace(0.0, 1.0, 9)
    kv_taus = np.quantile(mags, rhos)
    kv_taus[0] = 0.0  # curve contract: taus[0] == 0 (rho=0 kills nothing)
    return ThresholdCalculator({
        "kv": TransferCurve(taus=jnp.asarray(kv_taus, jnp.float32), rhos=jnp.asarray(rhos, jnp.float32)),
        "ffn_act": TransferCurve(taus=jnp.linspace(0.0, 0.2, 9), rhos=jnp.asarray(rhos, jnp.float32)),
        "attn_out": TransferCurve(taus=jnp.linspace(0.0, 0.05, 9), rhos=jnp.asarray(rhos, jnp.float32)),
    })


def _pallas_visit_counts() -> dict:
    """The fused paged decode kernel's page-visit counters under interpret
    mode: with nested dead-page sets growing with rho, the visited-page
    total must fall STRICTLY as rho rises (the kernel-level skip claim,
    deterministic — no wall clock involved)."""
    from repro.kernels.paged_attention import paged_decode_attention

    rng = np.random.default_rng(7)
    b, maxp, p, hkv, g, d = 2, 6, 4, 2, 2, 16
    num_pages = b * maxp + 1
    pool_k = jnp.asarray(rng.normal(size=(num_pages, p, hkv, d)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(num_pages, p, hkv, d)), jnp.float32)
    table = jnp.arange(1, num_pages, dtype=jnp.int32).reshape(b, maxp)
    q = jnp.asarray(rng.normal(size=(b, 1, hkv * g, d)), jnp.float32)
    lengths = jnp.asarray([maxp * p, maxp * p], jnp.int32)

    rhos, visits = (0.0, 0.25, 0.5, 0.75), []
    for rho in rhos:
        # kill the first ceil(rho * (maxp-1)) pages of every row outright:
        # dead sets are nested, so visits must fall strictly with rho (the
        # query's own page is the LAST page and stays live)
        occ = np.ones((num_pages, p), bool)
        dead = int(np.ceil(rho * (maxp - 1)))
        if dead:
            occ[np.asarray(table)[:, :dead].ravel()] = False
        _, n = paged_decode_attention(
            q, pool_k, pool_v, table, lengths,
            occupancy=jnp.asarray(occ), skip=True, with_visits=True, interpret=True,
        )
        visits.append(int(np.asarray(n).sum()))
    dec = all(a > b_ for a, b_ in zip(visits, visits[1:]))
    return {"rhos": list(rhos), "pages_visited": visits, "strictly_decreasing": dec}


def _run_sparsity_section(quick: bool) -> dict:
    """Tile-skipping on the serve path: (1) the skipping engine's tokens are
    IDENTICAL to its masked-reference twin at the same taus (the masked twin
    runs the same tiled datapath without skipping, so any divergence is a
    skip bug, not numerics); (2) tokens/s RISES with target rho — the
    "sparsity pays" claim, gated downstream as the rho=0.5 / rho=0 ratio;
    (3) Pallas visit counters fall strictly with rho."""
    cfg = _sparse_cfg()
    params = zoo.init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    prompt_len = 256
    new_tokens = 64
    n_req = 2 if quick else 4
    max_len = 336
    # the rho=0.5 / rho=0 ratio is HARD-floored at 1.0 downstream (same-run,
    # machine-independent), so the sweep shape maximises the attention share
    # of a decode step (long context -> many skippable pages, one-chunk
    # prefill so the identical-across-engines prefill cost doesn't dilute
    # the ratio) and the repeats are INTERLEAVED across rho values, so
    # monotonic machine drift cannot bias one rho's wall
    repeats = 5
    prompts = [rng.integers(1, cfg.vocab, size=prompt_len).tolist() for _ in range(n_req)]
    useful = n_req * new_tokens

    def build(tile_skip, rho, calculator=None):
        # page_size=1 makes every dead position a skippable page, so the page
        # skip fraction tracks rho directly; slots=1 keeps decode B=1 where
        # the per-token KV read dominates the step; decode_window=8 amortises
        # the per-step host dispatch that would otherwise dilute the ratio
        return ContinuousServeEngine(
            cfg, params,
            ContinuousServeConfig(slots=1, max_len=max_len, page_size=1, prefill_chunk=64,
                                  decode_window=8, target_rho=rho, tile_skip=tile_skip),
            calculator=calculator,
        )

    # profile the kv transfer curve off a short legacy-datapath run
    probe = build(None, 0.0)
    probe.generate(prompts[:1], max_new_tokens=4)
    calc = _profiled_calculator(probe)
    del probe

    rho_grid = (0.0, 0.5) if quick else (0.0, 0.25, 0.5, 0.75)
    parity_rho = 0.5
    skip_engines = {rho: build(True, rho, calc) for rho in rho_grid}

    # 1) engine-pair parity at a mid-range rho (greedy: token identity is
    # the engine-level exactness claim)
    mask_eng = build(False, parity_rho, calc)
    want = mask_eng.generate(prompts, max_new_tokens=new_tokens)
    got = skip_engines[parity_rho].generate(prompts, max_new_tokens=new_tokens)
    tile_skip_exact = want == got

    # 2) rho sweep: tokens/s and pool live fraction per target rho, repeats
    # interleaved (rho0 rep1, rho0.5 rep1, rho0 rep2, ...) so host-load drift
    # hits every rho equally
    for rho in rho_grid:
        skip_engines[rho].generate(prompts[:1], max_new_tokens=2)  # jit warmup
        skip_engines[rho].clear_history()
    walls = {rho: float("inf") for rho in rho_grid}
    round_ratios = []  # per-round paired wall(rho0) / wall(rho0.5)

    def sweep_round():
        w = {}
        for rho in rho_grid:
            eng = skip_engines[rho]
            t0 = time.perf_counter()
            for prompt in prompts:
                eng.submit(prompt, max_new_tokens=new_tokens)
            eng.run_until_complete()
            w[rho] = time.perf_counter() - t0
            walls[rho] = min(walls[rho], w[rho])
        round_ratios.append(w[0.0] / w[0.5])

    for _ in range(repeats):
        sweep_round()
    # the gated ratio is the MEDIAN of per-round PAIRED ratios: each round
    # times rho=0 and rho=0.5 back-to-back, so a sustained machine stall
    # multiplies both walls of that round and cancels in the quotient, and
    # the median discards rounds where a transient spike hit only one side.
    # (min-wall tok/s can't do this — it may compare walls from different
    # load epochs.)  when the median still sits near the hard floor, keep
    # sampling rather than gate on a noisy draw
    for _ in range(2):
        if statistics.median(round_ratios) > 1.02:
            break
        for _ in range(repeats):
            sweep_round()
    sweep = []
    for rho in rho_grid:
        m = skip_engines[rho].metrics()
        skip_engines[rho].clear_history()
        sweep.append({"rho": rho, "tok_per_s": useful / walls[rho],
                      "kv_live_frac": m["kv_occupancy_live"]})

    return {
        "tile_skip_exact": tile_skip_exact,
        "parity_rho": parity_rho,
        "rho_sweep": sweep,
        "rho05_vs_rho0": statistics.median(round_ratios),
        "rho05_round_ratios": [round(r, 4) for r in round_ratios],
        "pallas_visits": _pallas_visit_counts(),
    }


def _run_router_section(quick: bool) -> dict:
    """Multi-replica router (PR 8): a 2-replica fleet behind the async
    front-end must emit tokens IDENTICAL to the single-engine run on a
    shared-system-prompt workload (greedy rows are independent of
    placement — any divergence is a routing/handoff bug), with affinity
    hit-rate > 0 once the fleet is warm; a replica killed mid-decode must
    replay losslessly through drain + re-admit; and under a flood the
    router must climb the whole rho ladder BEFORE its first shed (ordering
    proven by the rho trace vs the shed tick).  The 2-replica vs single
    tokens/s ratio is a same-run, machine-independent number gated
    downstream."""
    from repro.router import Router, RouterPolicy

    cfg = _tiny_cfg()
    params = zoo.init_params(jax.random.PRNGKey(8), cfg)
    rng = np.random.default_rng(8)
    page_size = 8
    system = rng.integers(1, 256, size=4 * page_size).tolist()  # 4 shared pages
    n_req = 8 if quick else 24
    new_tokens = 8 if quick else 16
    wave1 = [system + rng.integers(1, 256, size=4).tolist() for _ in range(2)]
    wave2 = [system + rng.integers(1, 256, size=4).tolist() for _ in range(n_req)]
    useful = (len(wave1) + len(wave2)) * new_tokens
    warm_prompt = rng.integers(1, 256, size=8).tolist()  # no shared prefix

    def build(sparsity=None):
        c = cfg if sparsity is None else dataclasses.replace(
            cfg, name="bench-serve-router-dt", sparsity=sparsity
        )
        return ContinuousServeEngine(
            c, params,
            ContinuousServeConfig(slots=4, max_len=128, page_size=page_size, prefill_chunk=8),
        )

    def warmed(eng):
        eng.generate([warm_prompt], max_new_tokens=2)  # jit warmup
        eng.drop_prefix_cache()  # keep the affinity story cold
        eng.clear_history()
        return eng

    # --- single-engine reference: same staged workload, same submission order
    single = warmed(build())
    t0 = time.perf_counter()
    ref_reqs = [single.submit(p, max_new_tokens=new_tokens) for p in wave1]
    single.run_until_complete()
    ref_reqs += [single.submit(p, max_new_tokens=new_tokens) for p in wave2]
    single.run_until_complete()
    single_wall = time.perf_counter() - t0
    ref = [r.generated for r in ref_reqs]

    # --- 2-replica fleet, affinity routing on
    router = Router(
        [warmed(build()), warmed(build())], RouterPolicy(replica_depth_hw=6)
    )
    t0 = time.perf_counter()
    reqs = [router.submit(p, max_new_tokens=new_tokens) for p in wave1]
    router.run_until_complete()
    reqs += [router.submit(p, max_new_tokens=new_tokens) for p in wave2]
    router.run_until_complete()
    router_wall = time.perf_counter() - t0
    got = [r.generated for r in reqs]
    m = router.metrics()

    # --- drain/handoff: kill the loaded replica mid-decode, replay must be
    # lossless (the per-request reference is placement-independent, so the
    # staged run above already pins the expected tokens)
    drain_router = Router(
        [warmed(build()), warmed(build())], RouterPolicy(replica_depth_hw=2)
    )
    dreqs = [drain_router.submit(p, max_new_tokens=new_tokens) for p in wave2[:2]]
    for _ in range(8):  # into decode on both replicas
        drain_router.step()
    victim = next(i for i, h in enumerate(drain_router.replicas) if h.inflight)
    drain_router.health.kill(victim)
    drain_router.run_until_complete()
    router_drain = (
        [r.generated for r in dreqs] == ref[2:4]
        and drain_router.health.failovers == 1
        and all(not r.shed and not r.cancelled for r in dreqs)
    )

    # --- SLO ladder under overload: one dynatran replica, shallow queue cap.
    # Accuracy degrades by design as rho climbs (tokens are NOT compared);
    # the proven claim is the ORDER — every rung announced, saturation
    # reached, and only then the first shed
    ladder_eng = warmed(build(SparsityConfig(mode="dynatran", target_rho=0.0)))
    lrouter = Router(
        [ladder_eng],
        RouterPolicy(replica_depth_hw=2, queue_cap=6, depth_lo=2, depth_hi=8,
                     rho_ema=0.7, slo_p99_ms=200.0),
    )
    flood = 40 if quick else 80
    for _ in range(flood):
        lrouter.submit(rng.integers(1, 256, size=8).tolist(), max_new_tokens=4)
        lrouter.step()
    lrouter.run_until_complete()
    lm = lrouter.metrics()
    # the trace may oscillate AFTER the overload clears (rho stepping back
    # down as backlog drains is the ladder recovering, not a bug); the
    # ordering claim is about the climb: every rung announced, in order,
    # with the top rung reached no later than the first shed
    fst = lm["first_shed_tick"]
    climb = [] if fst is None else [rho for t, rho in lm["rho_trace"] if t <= fst]
    slo_ladder_ordered = lm["sheds"] > 0 and climb == lrouter.ladder.levels

    return {
        "replicas": 2,
        "requests": len(wave1) + len(wave2),
        "router_tokens_exact": got == ref,
        "router_drain": router_drain,
        "slo_ladder_ordered": slo_ladder_ordered,
        "affinity_hits": m["affinity_hits"],
        "affinity_hit_rate": m["affinity_hit_rate"],
        "sheds_parity_run": m["sheds"],
        "tok_per_s": useful / router_wall,
        "single_tok_per_s": useful / single_wall,
        "router2_vs_single": single_wall / router_wall,
        "ladder": {
            "sheds": lm["sheds"],
            "throttles": lm["throttles"],
            "rho_trace": lm["rho_trace"],
            "first_shed_tick": lm["first_shed_tick"],
            "completed": lm["completed"],
        },
    }


def _run_tiering_section(quick: bool) -> dict:
    """Host page tier (KV spill/restore): eviction writes a request's KV
    pages behind to a host-memory store and re-admission restores them with
    one device_put + re-link instead of replaying prefill.  Asserted
    claims: (1) the restored request's tokens are IDENTICAL to both the
    uncontended decode and the evict+replay run, for every paged kind
    (full / int8 / ring) — any divergence is a spill/restore bug, not
    numerics; (2) on a long-prompt re-admission workload the tiering
    engine beats the replay engine — the restore_vs_replay wall-clock
    ratio is HARD-floored at 1.0 downstream (same-run, machine-
    independent, paired-round median like the sparsity ratio)."""
    rng = np.random.default_rng(9)
    exact, activity = {}, {}

    def contended(eng, prompts, new):
        reqs = [eng.submit(p, max_new_tokens=new) for p in prompts]
        eng.run_until_complete()
        return [r.generated for r in reqs], reqs

    # per-kind parity under forced eviction: full/int8 evict under page
    # pressure on long-ish prompts; ring admits on one page, then first-lap
    # decode growth drains the tight ring pool (same shape as the eviction
    # tests in tests/test_paged_kv.py)
    int8_cfg = dataclasses.replace(_tiny_cfg(), name="bench-serve-tier-int8", kv_cache_dtype="int8")
    ring_cfg = ModelConfig(
        name="bench-serve-tier-ring", family="dense", layers=4, d_model=256, heads=8, kv_heads=4,
        d_ff=512, vocab=512, remat="none",
        attention_pattern=("sliding", "full"), window=8,
    )
    flavours = {
        "full": (_tiny_cfg(), dict(slots=3, num_pages=10), 12, 8),
        "int8": (int8_cfg, dict(slots=3, num_pages=10), 12, 8),
        "ring": (ring_cfg, dict(slots=4, num_pages_ring=7), 2, 16),
    }
    for kind, (c, tight, plen, new) in flavours.items():
        params = zoo.init_params(jax.random.PRNGKey(9), c)
        prompts = [rng.integers(1, 256, size=plen).tolist() for _ in range(5)]
        base = dict(max_len=64, page_size=4, prefill_chunk=4, prefix_caching=False)
        # the uncontended reference must be WIDTH-MATCHED to the contended
        # engines (same slots, default/ample pages -> never evicts): a
        # different decode batch width is a different compiled program, and
        # under --xla_force_host_platform_device_count the GEMM partitioning
        # shifts enough that int8 KV quantization rounds differently — that
        # is cross-width XLA drift, not a spill/restore bug
        straight = ContinuousServeEngine(
            c, params, ContinuousServeConfig(slots=tight["slots"], tiering=False, **base)
        )
        want = [straight.generate([p], max_new_tokens=new)[0] for p in prompts]
        if straight.metrics()["evictions"]:
            raise AssertionError(f"{kind}: reference engine evicted — not an uncontended baseline")
        replay = ContinuousServeEngine(c, params, ContinuousServeConfig(tiering=False, **base, **tight))
        replay_out, rreqs = contended(replay, prompts, new)
        tier = ContinuousServeEngine(c, params, ContinuousServeConfig(host_tier_mb=64.0, **base, **tight))
        tier_out, _ = contended(tier, prompts, new)
        ht = tier.metrics()["host_tier"]
        exact[kind] = want == replay_out == tier_out
        activity[kind] = {"evictions": sum(r.evictions for r in rreqs),
                          "spills": ht["spills"], "restores": ht["restores"]}

    # restore-vs-replay speedup: long prompts make replay (re-prefill the
    # whole prompt) expensive while restore stays one host->device copy.
    # Rounds are PAIRED (replay then tier back-to-back) and the gated ratio
    # is the round-ratio median, so machine drift cancels in the quotient
    cfg = _tiny_cfg()
    params = zoo.init_params(jax.random.PRNGKey(10), cfg)
    # new=16 in BOTH modes: decode growth past the 27-page pool is what
    # forces eviction (quick economizes via fewer repeats, never pressure)
    plen, new = 96, 16
    prompts = [rng.integers(1, 256, size=plen).tolist() for _ in range(4)]
    scfg = dict(slots=2, max_len=128, page_size=8, prefill_chunk=8,
                prefix_caching=False, num_pages=27)
    replay_eng = ContinuousServeEngine(cfg, params, ContinuousServeConfig(tiering=False, **scfg))
    tier_eng = ContinuousServeEngine(cfg, params, ContinuousServeConfig(host_tier_mb=64.0, **scfg))
    repeats = 3 if quick else 5
    round_ratios = []

    def sweep_round():
        w = {}
        for name, eng in (("replay", replay_eng), ("tier", tier_eng)):
            t0 = time.perf_counter()
            for p in prompts:
                eng.submit(p, max_new_tokens=new)
            eng.run_until_complete()
            w[name] = time.perf_counter() - t0
        round_ratios.append(w["replay"] / w["tier"])

    sweep_round()  # warmup: compiles prefill/decode AND the extract/insert jits
    round_ratios.clear()
    replay_eng.clear_history()
    tier_eng.clear_history()
    for _ in range(repeats):
        sweep_round()
    # median near the hard floor -> keep sampling rather than gate on noise
    for _ in range(2):
        if statistics.median(round_ratios) > 1.05:
            break
        for _ in range(repeats):
            sweep_round()
    ht = tier_eng.metrics()["host_tier"]
    if not ht["restores"] > 0:
        raise AssertionError("tiering ratio workload produced no restores — page pressure mis-tuned")
    return {
        "tier_restore_exact": all(exact.values()) and all(
            a["evictions"] > 0 and a["restores"] > 0 for a in activity.values()
        ),
        "per_kind_exact": exact,
        "per_kind_activity": activity,
        "restore_vs_replay": statistics.median(round_ratios),
        "round_ratios": [round(r, 4) for r in round_ratios],
        "ratio_workload": {"prompt_len": plen, "new_tokens": new, "requests": len(prompts)},
        "host_tier": ht,
    }


def _run_speculative_section(quick: bool) -> dict:
    """Speculative decoding (ISSUE 10): the draft pass proposes k tokens per
    sequence per tick and the target verifies all of them in ONE fused
    dispatch.  Asserted claims: (1) the speculative engine's streams are
    IDENTICAL to the non-speculative engine for every paged kind
    (full / int8 / ring), under forced eviction + replay mid-speculation,
    and with DynaTran draft pruning live (rejections exercise the
    page-rollback path) — any divergence is a rollback bug, not numerics;
    (2) speculation pays: the spec-vs-nonspec tokens/s ratio is HARD-
    floored at 1.0 downstream.  The gated configuration is self-speculation
    at draft_rho == rho (bit-identical draft and target logits -> every
    draft verifies), so one fused dispatch emits k+1 tokens where the
    non-speculative engine emits 1 — the win is host-dispatch
    amortization, the same effect AccelTran buys in hardware by keeping
    the datapath busy across dependent steps."""
    rng = np.random.default_rng(11)
    k = 3
    exact, acceptance = {}, {}

    def streams(c, p, scfg_kw, prompts, new):
        eng = ContinuousServeEngine(c, p, ContinuousServeConfig(**scfg_kw))
        reqs = [eng.submit(q, max_new_tokens=new) for q in prompts]
        eng.run_until_complete()
        return [r.generated for r in reqs], eng.metrics()

    # per-kind parity under page pressure: the tight pools force eviction +
    # replay mid-speculation (replayed requests re-speculate from their
    # restored length), the ring flavour wraps its window during the
    # speculative window
    int8_cfg = dataclasses.replace(_tiny_cfg(), name="bench-serve-spec-int8", kv_cache_dtype="int8")
    ring_cfg = ModelConfig(
        name="bench-serve-spec-ring", family="dense", layers=4, d_model=256, heads=8, kv_heads=4,
        d_ff=512, vocab=512, remat="none",
        attention_pattern=("sliding", "full"), window=8,
    )
    flavours = {
        "full": (_tiny_cfg(), dict(slots=3, num_pages=10), 12, 8),
        "int8": (int8_cfg, dict(slots=3, num_pages=10), 12, 8),
        "ring": (ring_cfg, dict(slots=4, num_pages_ring=7), 2, 16),
    }
    evictions = {}
    for kind, (c, tight, plen, new) in flavours.items():
        params = zoo.init_params(jax.random.PRNGKey(11), c)
        prompts = [rng.integers(1, 256, size=plen).tolist() for _ in range(5)]
        base = dict(max_len=64, page_size=4, prefill_chunk=4,
                    prefix_caching=False, tiering=False, **tight)
        want, m0 = streams(c, params, base, prompts, new)
        got, m1 = streams(c, params, dict(base, speculate=k), prompts, new)
        exact[kind] = want == got
        acceptance[kind] = m1["speculative"]["acceptance_rate"]
        evictions[kind] = m1["evictions"]

    # rejection parity: DynaTran draft pruning live (target rho=0, draft
    # rho=0.7 -> the draft sees pruned logits and mispredicts), so rejected
    # drafts drive the page-rollback path on every tick
    dcfg = _sparse_cfg()
    dparams = zoo.init_params(jax.random.PRNGKey(12), dcfg)
    dprompts = [rng.integers(1, dcfg.vocab, size=16).tolist() for _ in range(3)]
    dbase = dict(slots=3, max_len=96, page_size=4, prefill_chunk=8,
                 prefix_caching=False, target_rho=0.0)
    probe = ContinuousServeEngine(dcfg, dparams, ContinuousServeConfig(**dbase))
    probe.generate(dprompts[:1], max_new_tokens=4)
    calc = _profiled_calculator(probe)
    del probe
    def dyn_streams(kw):
        eng = ContinuousServeEngine(dcfg, dparams, ContinuousServeConfig(**kw), calculator=calc)
        reqs = [eng.submit(q, max_new_tokens=16) for q in dprompts]
        eng.run_until_complete()
        return [r.generated for r in reqs], eng.metrics()
    want, _ = dyn_streams(dbase)
    got, dm = dyn_streams(dict(dbase, speculate=k, draft_rho=0.7))
    exact["dynatran_draft"] = want == got
    acceptance["dynatran_draft"] = dm["speculative"]["acceptance_rate"]

    # cross-model draft: a random-init zoo draft predicts the target's
    # tokens ~never, so EVERY tick rejects and rolls the speculative pages
    # back — the guaranteed-rollback parity angle (correctness must be
    # independent of acceptance; only throughput depends on it)
    ccfg = _tiny_cfg()
    cparams = zoo.init_params(jax.random.PRNGKey(14), ccfg)
    cprompts = [rng.integers(1, 256, size=8).tolist() for _ in range(3)]
    cbase = dict(slots=3, max_len=64, page_size=4, prefill_chunk=4, prefix_caching=False)
    want, _ = streams(ccfg, cparams, cbase, cprompts, 12)
    got, cm = streams(ccfg, cparams, dict(cbase, speculate=k, draft_arch="deepseek-7b"), cprompts, 12)
    exact["cross_draft"] = want == got
    acceptance["cross_draft"] = cm["speculative"]["acceptance_rate"]
    rollbacks_exercised = acceptance["cross_draft"] < 1.0

    # spec-vs-nonspec speedup on a dispatch-dominated workload: a model
    # small enough that per-dispatch host overhead (scheduler bookkeeping,
    # argument staging, jit call) dominates per-step compute — exactly the
    # regime speculation targets.  Self-spec at draft_rho == rho means the
    # draft and target logits are bit-identical, every draft verifies, and
    # one fused dispatch emits k+1 tokens where the baseline emits 1; the
    # k extra draft steps ride inside the same dispatch.  (On a compute-
    # dominated model self-spec costs 2k+1 model steps per k+1 tokens and
    # cannot pay — the win is amortization, not FLOP reduction.)  Rounds
    # are PAIRED (nonspec then spec back-to-back) and the gated ratio is
    # the round-ratio median, so machine drift cancels in the quotient —
    # same protocol as the sparsity and tiering ratios
    cfg = ModelConfig(
        name="bench-serve-spec-tiny", family="dense", layers=2, d_model=64, heads=4,
        kv_heads=4, d_ff=128, vocab=128, remat="none",
    )
    params = zoo.init_params(jax.random.PRNGKey(13), cfg)
    plen, new = 8, 32 if quick else 64
    prompts = [rng.integers(1, cfg.vocab, size=plen).tolist() for _ in range(4)]
    scfg = dict(slots=2, max_len=128, page_size=8, prefill_chunk=8, prefix_caching=False)
    nonspec_eng = ContinuousServeEngine(cfg, params, ContinuousServeConfig(**scfg))
    spec_eng = ContinuousServeEngine(cfg, params, ContinuousServeConfig(speculate=k, **scfg))
    repeats = 3 if quick else 5
    round_ratios = []

    walls = {"nonspec": float("inf"), "spec": float("inf")}
    spec_streams_equal = True

    def sweep_round():
        nonlocal spec_streams_equal
        w, outs = {}, {}
        for name, eng in (("nonspec", nonspec_eng), ("spec", spec_eng)):
            t0 = time.perf_counter()
            reqs = [eng.submit(p, max_new_tokens=new) for p in prompts]
            eng.run_until_complete()
            w[name] = time.perf_counter() - t0
            walls[name] = min(walls[name], w[name])
            outs[name] = [r.generated for r in reqs]
        round_ratios.append(w["nonspec"] / w["spec"])
        # the ratio workload doubles as a parity check: every paired round
        # must emit identical greedy streams
        spec_streams_equal = spec_streams_equal and outs["spec"] == outs["nonspec"]

    sweep_round()  # warmup: compiles prefill/decode AND the fused spec scan
    round_ratios.clear()
    for _ in range(repeats):
        sweep_round()
    for _ in range(2):
        if statistics.median(round_ratios) > 1.05:
            break
        for _ in range(repeats):
            sweep_round()
    sm = spec_eng.metrics()["speculative"]
    ticks = sm["drafted"] // k  # speculative dispatches issued
    useful = len(prompts) * new
    return {
        "k": k,
        "spec_tokens_exact": all(exact.values()) and spec_streams_equal
        and rollbacks_exercised and all(e > 0 for e in evictions.values()),
        "per_kind_exact": exact,
        "per_kind_acceptance": acceptance,
        "per_kind_evictions": evictions,
        "acceptance_rate": sm["acceptance_rate"],
        "accepted_tokens_per_step": (sm["accepted"] + ticks) / ticks if ticks else None,
        "tok_per_s": useful / walls["spec"],
        "tok_per_s_nonspec": useful / walls["nonspec"],
        "spec_vs_nonspec": statistics.median(round_ratios),
        "round_ratios": [round(r, 4) for r in round_ratios],
        "ratio_workload": {"prompt_len": plen, "new_tokens": new, "requests": len(prompts)},
    }


def _run_analysis_section() -> bool:
    """Zero-tolerance ``analysis_clean`` flag: the static reprolint checkers
    (retrace / host-device / donation / Pallas) against the committed
    baseline.  Emitting it from bench-smoke means the regression gate and the
    ``lint-invariants`` CI lane enforce the same contract and cannot silently
    drift apart (the harness half runs in the lint lane — it needs its own
    engine and would double this bench's wall)."""
    from repro.analysis import run_static

    new, stale = run_static()
    for f in new:
        print(f"  reprolint: {f.format()}")
    for e in stale:
        print(f"  reprolint: STALE baseline entry: {e.format()}")
    return not new and not stale


def _request_mix(n: int, prompt_len: int, short_new: int, long_new: int, rng) -> list[tuple[list[int], int]]:
    """75% short / 25% long generations, shuffled so waves mix both."""
    reqs = []
    for i in range(n):
        prompt = rng.integers(1, 256, size=prompt_len).tolist()
        new = long_new if i % 4 == 0 else short_new
        reqs.append((prompt, new))
    rng.shuffle(reqs)
    return reqs


def _run_baseline(engine, requests, slots):
    """Wave-at-a-time serving: every wave decodes to its longest request."""
    t0 = time.perf_counter()
    outs, latencies = [], []
    for w0 in range(0, len(requests), slots):
        wave = requests[w0 : w0 + slots]
        wave_new = max(new for _, new in wave)
        got = engine.generate([p for p, _ in wave], max_new_tokens=wave_new)
        t_wave = time.perf_counter() - t0
        for (_, new), row in zip(wave, got):
            outs.append(row[:new])
            latencies.append(t_wave)  # all submitted at t0; wave finishes together
    wall = time.perf_counter() - t0
    return outs, latencies, wall


def _run_continuous(engine, requests):
    engine.clear_history()
    t0 = time.perf_counter()
    reqs = [engine.submit(p, max_new_tokens=new) for p, new in requests]
    engine.run_until_complete()
    wall = time.perf_counter() - t0
    outs = [r.generated for r in reqs]
    latencies = [r.latency() for r in reqs]
    return outs, latencies, wall, engine.metrics()


def run(quick: bool = False) -> dict:
    banner("serve: paged-KV continuous batching vs slot-granularity baseline")
    cfg = _tiny_cfg()
    params = zoo.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    slots = 4
    n_req = 8 if quick else 48
    prompt_len = 8
    short_new, long_new = (4, 32) if quick else (4, 96)
    max_len = 128
    repeats = 1 if quick else 3
    requests = _request_mix(n_req, prompt_len, short_new, long_new, rng)
    useful = sum(new for _, new in requests)

    baseline = ServeEngine(cfg, params, ServeConfig(slots=slots, max_len=max_len))
    baseline.generate([p for p, _ in requests[:slots]], max_new_tokens=2)  # jit warmup
    continuous = ContinuousServeEngine(
        cfg, params, ContinuousServeConfig(slots=slots, max_len=max_len, page_size=8, prefill_chunk=8)
    )
    continuous.generate([p for p, _ in requests[:slots]], max_new_tokens=2)  # jit warmup

    # best-of-N on shared warmed engines: wall-clock on a busy CPU host is
    # noisy and the claim under test is structural, not load-dependent
    b_wall = c_wall = float("inf")
    for _ in range(repeats):
        outs, lat, wall = _run_baseline(baseline, requests, slots)
        if wall < b_wall:
            b_outs, b_lat, b_wall = outs, lat, wall
        outs, lat, wall, metrics = _run_continuous(continuous, requests)
        if wall < c_wall:
            c_outs, c_lat, c_wall, c_metrics = outs, lat, wall, metrics

    # correctness: same tokens from both engines (greedy; prompts replayed
    # identically), plus a B=1/chunk=1 run that is bitwise-bound to the
    # dense-KV reference by construction
    match_all = b_outs == c_outs
    ident_reqs = requests[:3]
    base1 = ServeEngine(cfg, params, ServeConfig(slots=1, max_len=max_len))
    ref = [base1.generate([p], max_new_tokens=new)[0] for p, new in ident_reqs]
    eng1 = ContinuousServeEngine(
        cfg, params, ContinuousServeConfig(slots=1, max_len=max_len, page_size=8, prefill_chunk=1)
    )
    got = [eng1.generate([p], max_new_tokens=new)[0] for p, new in ident_reqs]
    bitwise = ref == got

    ring = _run_ring_section(quick)
    prefix = _run_prefix_section(quick)
    tp = _run_tp_section(quick)
    families = _run_families_section(quick)
    sparsity = _run_sparsity_section(quick)
    router = _run_router_section(quick)
    tiering = _run_tiering_section(quick)
    speculative = _run_speculative_section(quick)

    speedup = (useful / c_wall) / (useful / b_wall)
    analysis_clean = _run_analysis_section()
    result = {
        "analysis_clean": analysis_clean,
        "sparsity": sparsity,
        "router": router,
        "tiering": tiering,
        "speculative": speculative,
        "ring": ring,
        "prefix_cache": prefix,
        "tp": tp,
        "families": families,
        "requests": n_req,
        "useful_tokens": useful,
        "baseline": {
            "tok_per_s": useful / b_wall,
            "wall_s": b_wall,
            "p50_latency_s": _pct(sorted(b_lat), 0.50),
            "p99_latency_s": _pct(sorted(b_lat), 0.99),
        },
        "continuous": {
            "tok_per_s": useful / c_wall,
            "wall_s": c_wall,
            "p50_latency_s": _pct(sorted(c_lat), 0.50),
            "p99_latency_s": _pct(sorted(c_lat), 0.99),
            "evictions": c_metrics["evictions"],
        },
        "speedup": speedup,
        "outputs_match_baseline": match_all,
        "bitwise_identical_rho0": bitwise,
    }
    print(
        f"  baseline   : {result['baseline']['tok_per_s']:7.1f} tok/s  "
        f"p50 {result['baseline']['p50_latency_s']:.3f}s p99 {result['baseline']['p99_latency_s']:.3f}s"
    )
    print(
        f"  continuous : {result['continuous']['tok_per_s']:7.1f} tok/s  "
        f"p50 {result['continuous']['p50_latency_s']:.3f}s p99 {result['continuous']['p99_latency_s']:.3f}s"
    )
    print(f"  speedup {speedup:.2f}x | outputs match: {match_all} | bitwise @ rho=0: {bitwise}")
    ring_mb = [(s["max_len"], s["ring_pool_bytes"] / 1e6, s["dense_cache_bytes"] / 1e6) for s in ring["memory_scaling"]]
    print(
        f"  ring       : {ring['tok_per_s']:7.1f} tok/s  bitwise @ rho=0: {ring['bitwise_identical_rho0']} | "
        f"ring pool MB vs dense MB over max_len: "
        + ", ".join(f"{ml}: {r:.2f}/{d:.2f}" for ml, r, d in ring_mb)
    )
    print(
        f"  prefix     : hit rate {prefix['hit_rate']:.2f}, {prefix['pages_shared']} page links shared | "
        f"burst peak pages {prefix['peak_pages_in_use']} vs {prefix['peak_pages_in_use_no_sharing']} unshared | "
        f"tokens identical: {prefix['tokens_identical_to_uncached']} | "
        f"drained: {prefix['allocator_drained_at_shutdown']}"
    )
    print(
        f"  cold burst : {prefix['burst_relinked_pages']} pages relinked mid-wave | "
        f"pages at ready {prefix['burst_pages_at_ready']} vs {prefix['burst_pages_at_ready_no_sharing']} unshared | "
        f"tokens identical: {prefix['burst_tokens_identical']}"
    )
    if "skipped" in tp:
        print(f"  tp         : skipped ({tp['skipped']})")
    else:
        scale_str = ", ".join(
            f"tp={s['tp']}: {s['tok_per_s']:.1f} tok/s {s['pool_bytes_per_shard'] / 1e6:.2f} MB/shard"
            for s in tp["scaling"]
        )
        print(f"  tp         : bitwise {tp['bitwise_identical_tp']} | {scale_str}")
    rw, wh = families["rwkv6"], families["whisper"]
    print(
        f"  rwkv6      : {rw['tok_per_s']:7.1f} tok/s (slot engine {rw['slot_tok_per_s']:.1f}) | "
        f"tokens match dense: {rw['tokens_match_dense']} | "
        f"state bytes flat in max_len: {rw['state_bytes_flat_in_max_len']} "
        f"({rw['state_bytes']['slot'] / 1e3:.1f} kB slot-dense, 0 paged)"
    )
    print(
        f"  whisper    : {wh['tok_per_s']:7.1f} tok/s | tokens match dense: {wh['tokens_match_dense']} | "
        f"drained: {wh['allocator_drained']} | "
        f"cross-KV {wh['state_bytes']['slot'] / 1e3:.1f} kB slot-dense + "
        f"{wh['state_bytes']['paged'] / 1e3:.1f} kB paged self-KV"
    )
    sweep_str = ", ".join(
        f"rho={s['rho']:.2f}: {s['tok_per_s']:.1f} tok/s (live {s['kv_live_frac']:.2f})"
        for s in sparsity["rho_sweep"]
    )
    pv = sparsity["pallas_visits"]
    print(
        f"  sparsity   : skip == mask tokens @ rho={sparsity['parity_rho']}: {sparsity['tile_skip_exact']} | "
        f"{sweep_str} | rho0.5/rho0 {sparsity['rho05_vs_rho0']:.2f}x"
    )
    print(
        f"               pallas pages visited over rho {pv['rhos']}: {pv['pages_visited']} "
        f"(strictly decreasing: {pv['strictly_decreasing']})"
    )
    tht = tiering["host_tier"]
    print(
        f"  tiering    : restore exact {tiering['per_kind_exact']} | "
        f"restore/replay {tiering['restore_vs_replay']:.2f}x on "
        f"{tiering['ratio_workload']['prompt_len']}-token prompts | "
        f"{tht['spills']} spills, {tht['restores']} restores, "
        f"{tht['tier_replays']} tier replays (ratio {tht['restore_ratio']})"
    )
    sp = speculative
    print(
        f"  speculative: k={sp['k']} | streams exact {sp['per_kind_exact']} | "
        f"{sp['accepted_tokens_per_step']:.2f} tokens/dispatch "
        f"(acceptance {sp['acceptance_rate']:.2f}) | "
        f"{sp['tok_per_s']:.1f} tok/s spec vs {sp['tok_per_s_nonspec']:.1f} nonspec "
        f"-> {sp['spec_vs_nonspec']:.2f}x"
    )
    rt = router["ladder"]
    print(
        f"  router     : {router['tok_per_s']:7.1f} tok/s on 2 replicas "
        f"({router['router2_vs_single']:.2f}x vs single) | "
        f"tokens exact: {router['router_tokens_exact']} | drain lossless: {router['router_drain']} | "
        f"affinity hit rate {router['affinity_hit_rate']:.2f}"
    )
    print(
        f"               slo ladder: rho trace {rt['rho_trace']} -> "
        f"{rt['sheds']} sheds from tick {rt['first_shed_tick']} "
        f"(ordered: {router['slo_ladder_ordered']})"
    )
    save("serve_continuous", result)
    if not sparsity["tile_skip_exact"]:
        raise AssertionError("tile-skipped decode diverged from its masked-reference twin")
    if not pv["strictly_decreasing"]:
        raise AssertionError("Pallas page-visit counts did not fall strictly with rho")
    if not quick and sparsity["rho05_vs_rho0"] <= 1.0:
        raise AssertionError(
            f"tile skipping did not pay: rho=0.5 vs rho=0 tokens/s ratio "
            f"{sparsity['rho05_vs_rho0']:.3f} <= 1.0"
        )
    if not bitwise:
        raise AssertionError("paged decode diverged from dense-KV reference at rho=0")
    if not ring["bitwise_identical_rho0"]:
        raise AssertionError("ring-paged decode diverged from dense-KV reference at rho=0")
    if not ring["ring_bytes_flat_in_max_len"]:
        raise AssertionError("ring pool bytes grew with max_len — ring paging is not window-bound")
    if not prefix["tokens_identical_to_uncached"]:
        raise AssertionError("prefix caching changed the emitted tokens")
    if not prefix["hit_rate"] > 0:
        raise AssertionError("shared-system-prompt workload never hit the prefix cache")
    if not prefix["peak_pages_in_use"] < prefix["peak_pages_in_use_no_sharing"]:
        raise AssertionError("prefix sharing did not reduce pages in use")
    if not prefix["allocator_drained_at_shutdown"]:
        raise AssertionError("allocator did not drain to empty after drop_prefix_cache")
    if not prefix["burst_tokens_identical"]:
        raise AssertionError("same-wave dedup changed the emitted tokens")
    if not prefix["burst_relinked_pages"] > 0:
        raise AssertionError("cold same-tick burst never relinked a page mid-wave")
    if not prefix["burst_pages_at_ready"] < prefix["burst_pages_at_ready_no_sharing"]:
        raise AssertionError("same-wave dedup did not reduce pages held at prefill completion")
    if "skipped" not in tp:
        for kind, ok in tp["bitwise_identical_tp"].items():
            if not ok:
                raise AssertionError(f"TP decode diverged from the single-device engine ({kind} pages)")
        for s in tp["scaling"]:
            if not s["shard_bytes_exact"]:
                raise AssertionError(f"tp={s['tp']}: per-shard pool bytes != total/N")
    if not rw["tokens_match_dense"]:
        raise AssertionError("rwkv6 continuous decode diverged from the dense-state replay")
    if not rw["state_bytes_flat_in_max_len"]:
        raise AssertionError("rwkv6 decode-state bytes grew with max_len — slot-dense state is not O(1)/slot")
    if not wh["tokens_match_dense"]:
        raise AssertionError("whisper continuous decode diverged from the dense-state replay")
    if not wh["allocator_drained"]:
        raise AssertionError("whisper allocator did not drain after run_until_complete")
    if not router["router_tokens_exact"]:
        raise AssertionError("2-replica router emitted different tokens than the single engine")
    if not router["router_drain"]:
        raise AssertionError("mid-decode replica kill was not replayed losslessly through the router")
    if not router["slo_ladder_ordered"]:
        raise AssertionError(
            "router shed before saturating the rho ladder — degradation order violated"
        )
    if not router["affinity_hit_rate"] > 0:
        raise AssertionError("warm shared-prefix fleet never scored an affinity hit")
    if not tiering["tier_restore_exact"]:
        raise AssertionError(
            f"host-tier restore diverged from straight decode / evict+replay "
            f"(per-kind: {tiering['per_kind_exact']}, activity: {tiering['per_kind_activity']})"
        )
    if not quick and tiering["restore_vs_replay"] <= 1.0:
        raise AssertionError(
            f"host-tier restore did not beat replay: restore_vs_replay "
            f"{tiering['restore_vs_replay']:.3f} <= 1.0"
        )
    if not speculative["spec_tokens_exact"]:
        raise AssertionError(
            f"speculative decode diverged from the non-speculative engine "
            f"(per-kind: {speculative['per_kind_exact']}, "
            f"evictions: {speculative['per_kind_evictions']})"
        )
    if not quick and speculative["spec_vs_nonspec"] <= 1.0:
        raise AssertionError(
            f"speculation did not pay: spec_vs_nonspec "
            f"{speculative['spec_vs_nonspec']:.3f} <= 1.0"
        )
    if not quick and speedup < 1.5:
        raise AssertionError(f"continuous batching speedup {speedup:.2f}x < 1.5x target")
    return result


if __name__ == "__main__":
    run()
