"""Bench regression gate: compare a fresh ``serve_continuous`` result
against the committed baseline (``BENCH_serve.json`` at the repo root).

    python -m benchmarks.check_regression \
        [--baseline BENCH_serve.json] \
        [--fresh experiments/bench/serve_continuous.json] \
        [--out experiments/bench/serve_trajectory.json] \
        [--tolerance 0.25]

Two gate classes:

* **Parity** — every bitwise/equivalence assertion the bench records must
  hold: paged-vs-dense bitwise at rho=0, ring bitwise + window-bound
  memory, prefix-cache token identity (warm and cold-burst), allocator
  drain, TP bitwise parity per page kind and the per-shard = total/N
  memory split (when a multi-device mesh was available), tile-skip vs
  masked-twin token identity and strictly-falling Pallas page-visit
  counts, and the multi-replica router's placement-invisibility claims
  (2-replica tokens == single-engine tokens, lossless drain after a
  replica kill, rho ladder fully climbed before the first shed, affinity
  hit rate > 0 on a warm fleet), and the host page tier's restore
  exactness (restored tokens == straight decode == evict+replay, every
  paged kind).  Any false flag fails the gate outright — no tolerance.
  Same-run ratios with HARD floors are also parity-class: the sparsity
  section's rho=0.5 / rho=0 tokens/s ratio (> 1.0 — tile skipping that
  does not pay fails the gate), the router's 2-replica / single-engine
  ratio (> 0.25 — bounded routing overhead), and the tier's
  restore-vs-replay ratio (> 1.0 — restoring must beat re-prefilling).
* **Throughput** — tokens/s ratios must not regress more than
  ``tolerance`` (default 25%) below the baseline.  Gated on MACHINE-
  INDEPENDENT ratios (each engine's tokens/s normalised by the same run's
  slot-granularity baseline engine), so a slower CI runner cannot
  false-fail the gate; raw tokens/s are recorded in the trajectory for
  tracking but never gated.

The merged trajectory (baseline + fresh + deltas) is written to ``--out``
and uploaded as a CI artifact.  ``--update-baseline`` rewrites the
baseline from the fresh run (maintenance; commit the result).
"""
from __future__ import annotations

import argparse
import json
import sys

PARITY_FLAGS = [
    ("bitwise_identical_rho0", ("bitwise_identical_rho0",)),
    ("outputs_match_baseline", ("outputs_match_baseline",)),
    # reprolint static invariants (ISSUE 7): the bench emits the same
    # zero-tolerance flag the lint-invariants CI lane enforces, so the
    # regression gate and the lint lane cannot drift apart
    ("analysis_clean", ("analysis_clean",)),
    ("ring_bitwise", ("ring", "bitwise_identical_rho0")),
    ("ring_bytes_flat", ("ring", "ring_bytes_flat_in_max_len")),
    ("prefix_tokens_identical", ("prefix_cache", "tokens_identical_to_uncached")),
    ("prefix_drained", ("prefix_cache", "allocator_drained_at_shutdown")),
    ("burst_tokens_identical", ("prefix_cache", "burst_tokens_identical")),
    # DecodeState families (ISSUE 5): slot-dense state correctness claims
    ("rwkv6_tokens_match_dense", ("families", "rwkv6", "tokens_match_dense")),
    ("rwkv6_state_bytes_flat", ("families", "rwkv6", "state_bytes_flat_in_max_len")),
    ("whisper_tokens_match_dense", ("families", "whisper", "tokens_match_dense")),
    ("whisper_drained", ("families", "whisper", "allocator_drained")),
    # tiled DynaTran datapath (ISSUE 6): skipping must be invisible in the
    # tokens and visible in the visit counters — both zero-tolerance
    ("tile_skip_exact", ("sparsity", "tile_skip_exact")),
    ("sparsity_visits_decreasing", ("sparsity", "pallas_visits", "strictly_decreasing")),
    # multi-replica router (ISSUE 8): placement must be invisible in the
    # tokens (2-replica fleet == single engine), a killed replica must
    # replay losslessly, and shedding may begin only after the whole rho
    # ladder has been climbed — all zero-tolerance
    ("router_tokens_exact", ("router", "router_tokens_exact")),
    ("router_drain", ("router", "router_drain")),
    ("router_slo_ladder_ordered", ("router", "slo_ladder_ordered")),
    # host page tier (ISSUE 9): a restored request's tokens must be
    # bitwise-identical to both the straight decode and the evict+replay
    # run, for every paged kind — zero-tolerance
    ("tier_restore_exact", ("tiering", "tier_restore_exact")),
    # speculative decoding (ISSUE 10): the speculative path must be
    # invisible in the tokens — spec streams bitwise-identical to the
    # non-speculative engine for every paged kind — zero-tolerance
    ("spec_tokens_exact", ("speculative", "spec_tokens_exact")),
]

# same-run tokens/s ratio floors (machine-independent, so no tolerance):
# the whole point of tile skipping is throughput — a ratio at or below the
# floor means sparsity stopped paying, which is a regression even when every
# exactness flag holds
RATIO_FLOORS = [
    ("rho05_vs_rho0", ("sparsity", "rho05_vs_rho0"), 1.0),
    # router overhead bound: a 2-replica fleet interleaves both engines'
    # steps on one host, so its tokens/s trails the single engine — but it
    # must stay within a bounded factor (queueing + placement are cheap;
    # anything below the floor means the router is doing device work or
    # serializing pathologically).  Floor is deliberately loose: the same-
    # run ratio is wall-clock based and CPU CI runners are noisy
    ("router2_vs_single", ("router", "router2_vs_single"), 0.25),
    # host page tier: restoring spilled pages must beat replaying prefill
    # on the long-prompt re-admission workload — a ratio at or below 1.0
    # means the tier is pure overhead, a regression even when exact
    ("tier_restore_vs_replay", ("tiering", "restore_vs_replay"), 1.0),
    # speculative decoding: verifying k+1 positions in one fused dispatch
    # must beat one-token-per-dispatch decode on the same run — a ratio at
    # or below 1.0 means speculation is pure overhead, a regression even
    # when every stream is exact
    ("spec_vs_nonspec", ("speculative", "spec_vs_nonspec"), 1.0),
]


def _get(d: dict, path: tuple, default=None):
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return default
        d = d[k]
    return d


def throughput_ratios(result: dict) -> dict:
    """Machine-independent tokens/s ratios: every engine normalised by the
    same run's slot-granularity baseline engine."""
    base = _get(result, ("baseline", "tok_per_s"))
    if not base:
        return {}
    out = {"speedup": result.get("speedup")}
    ring = _get(result, ("ring", "tok_per_s"))
    if ring:
        out["ring_vs_slot"] = ring / base
    prefix = _get(result, ("prefix_cache", "tok_per_s"))
    if prefix:
        out["prefix_vs_slot"] = prefix / base
    for s in _get(result, ("tp", "scaling"), ()) or ():
        out[f"tp{s['tp']}_vs_slot"] = s["tok_per_s"] / base
    # rwkv6 normalises against ITS OWN slot-granularity run (a different
    # model than the main section's engine pair)
    rwkv = _get(result, ("families", "rwkv6", "tok_per_s"))
    rwkv_slot = _get(result, ("families", "rwkv6", "slot_tok_per_s"))
    if rwkv and rwkv_slot:
        out["rwkv6_vs_slot"] = rwkv / rwkv_slot
    # already a same-run ratio (and floored hard in check_parity); tracked
    # here so the trajectory shows how much sparsity pays over time
    out["rho05_vs_rho0"] = _get(result, ("sparsity", "rho05_vs_rho0"))
    # router fleet vs single engine (ISSUE 8): same-run wall-clock ratio,
    # floored hard in check_parity and tracked here for the trajectory
    out["router2_vs_single"] = _get(result, ("router", "router2_vs_single"))
    # host page tier (ISSUE 9): restore-vs-replay paired-round median,
    # floored hard in check_parity and tracked here for the trajectory
    out["tier_restore_vs_replay"] = _get(result, ("tiering", "restore_vs_replay"))
    # speculative decoding (ISSUE 10): spec-vs-nonspec paired-round median,
    # floored hard in check_parity and tracked here for the trajectory
    out["spec_vs_nonspec"] = _get(result, ("speculative", "spec_vs_nonspec"))
    return {k: v for k, v in out.items() if v is not None}


def check_parity(result: dict) -> list[str]:
    failures = []
    for name, path in PARITY_FLAGS:
        val = _get(result, path)
        if val is not True:
            failures.append(f"parity: {name} is {val!r} (expected True)")
    if not _get(result, ("prefix_cache", "burst_relinked_pages"), 0) > 0:
        failures.append("parity: cold burst never relinked a page mid-wave")
    tp = result.get("tp", {})
    if tp and "skipped" not in tp:
        for kind, ok in tp.get("bitwise_identical_tp", {}).items():
            if ok is not True:
                failures.append(f"parity: TP decode diverged from single-device ({kind} pages)")
        for s in tp.get("scaling", ()):
            if s.get("shard_bytes_exact") is not True:
                failures.append(f"parity: tp={s['tp']} per-shard pool bytes != total/N")
    if not _get(result, ("router", "affinity_hit_rate"), 0) > 0:
        failures.append("parity: warm shared-prefix fleet never scored an affinity hit")
    for name, path, floor in RATIO_FLOORS:
        val = _get(result, path)
        if not (isinstance(val, (int, float)) and val > floor):
            failures.append(
                f"parity: {name} is {val!r} (hard same-run floor > {floor})"
            )
    return failures


def check_throughput(fresh: dict, baseline: dict, tolerance: float) -> tuple[list[str], dict]:
    fresh_r = throughput_ratios(fresh)
    base_r = baseline.get("throughput_ratios", {})
    tp_skipped = "skipped" in (fresh.get("tp") or {})
    failures, deltas = [], {}
    for key, base_val in base_r.items():
        got = fresh_r.get(key)
        if got is None:
            if key.startswith("tp") and tp_skipped:
                # the bench ran on a single device and reported its TP
                # section as skipped — a legitimate local run, not a
                # regression (CI forces a multi-device mesh via XLA_FLAGS)
                continue
            failures.append(f"throughput: metric {key} missing from the fresh run")
            continue
        deltas[key] = {"baseline": base_val, "fresh": got, "rel": got / base_val}
        if got < (1.0 - tolerance) * base_val:
            failures.append(
                f"throughput: {key} regressed {(1 - got / base_val):.0%} "
                f"({got:.3f} vs baseline {base_val:.3f}, tolerance {tolerance:.0%})"
            )
    return failures, deltas


def make_baseline(result: dict) -> dict:
    return {
        "bench": "serve_continuous",
        "throughput_ratios": throughput_ratios(result),
        "raw_tok_per_s": {
            "slot_baseline": _get(result, ("baseline", "tok_per_s")),
            "continuous": _get(result, ("continuous", "tok_per_s")),
        },
        "note": (
            "Gated metrics are tokens/s RATIOS vs the same run's slot-"
            "granularity engine (machine-independent); raw tok/s is "
            "informational. Regenerate with --update-baseline."
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_serve.json")
    ap.add_argument("--fresh", default="experiments/bench/serve_continuous.json")
    ap.add_argument("--out", default="experiments/bench/serve_trajectory.json")
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the fresh run and exit")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = json.load(f)

    if args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump(make_baseline(fresh), f, indent=1, default=float)
            f.write("\n")
        print(f"[gate] baseline rewritten: {args.baseline}")
        return

    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = check_parity(fresh)
    tput_failures, deltas = check_throughput(fresh, baseline, args.tolerance)
    failures += tput_failures

    trajectory = {
        "baseline": baseline,
        "fresh": {"throughput_ratios": throughput_ratios(fresh), "result": fresh},
        "deltas": deltas,
        "tolerance": args.tolerance,
        "failures": failures,
        "passed": not failures,
    }
    with open(args.out, "w") as f:
        json.dump(trajectory, f, indent=1, default=float)

    for key, d in sorted(deltas.items()):
        print(f"[gate] {key}: {d['fresh']:.3f} vs baseline {d['baseline']:.3f} ({d['rel']:.0%})")
    if failures:
        print("[gate] FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        sys.exit(1)
    print(f"[gate] passed ({len(deltas)} throughput metrics within {args.tolerance:.0%}, all parity flags hold)")


if __name__ == "__main__":
    main()
