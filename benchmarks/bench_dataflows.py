"""Paper Fig. 15: dynamic energy + reuse instances for all 24 dataflows
under the paper's three W x A scenarios, 4 MAC lanes."""
from __future__ import annotations

from repro.core.dataflow import compare_dataflows

from .common import banner, save

SCENARIOS = {
    "a": ((4, 64, 64), (4, 64, 64)),
    "b": ((4, 64, 64), (4, 64, 128)),
    "c": ((4, 128, 64), (4, 64, 64)),
}


def run(quick: bool = False) -> dict:
    banner("Fig. 15: 24 dataflows x 3 scenarios")
    out = {}
    for name, (w, a) in SCENARIOS.items():
        ranked = compare_dataflows(w, a, lanes=4)
        out[name] = [
            {
                "dataflow": s.name,
                "dynamic_energy_nj": s.dynamic_energy_nj,
                "reuse_instances": s.reuse_instances,
                "w_loads": s.w_loads,
                "a_loads": s.a_loads,
            }
            for s in ranked
        ]
        best = ranked[0]
        worst = ranked[-1]
        print(
            f"  scenario {name}: best {best.name} ({best.dynamic_energy_nj:.0f} nJ, "
            f"{best.reuse_instances} reuse) worst {worst.name} ({worst.dynamic_energy_nj:.0f} nJ)"
        )
        # paper Fig. 15: [b,i,j,k] minimises energy.  In our lane-register
        # replay it ties exactly for the symmetric scenario (a) and lands
        # within 1% of the minimum for the asymmetric ones (the tie group
        # shifts with the I/J aspect ratio) — assert both.
        bijk = next(s for s in ranked if s.name == "[b,i,j,k]")
        assert bijk.dynamic_energy_nj <= best.dynamic_energy_nj * 1.01, name
        if name == "a":
            assert bijk.dynamic_energy_nj <= best.dynamic_energy_nj * (1 + 1e-9)
    save("dataflows", out)
    return out


if __name__ == "__main__":
    run()
