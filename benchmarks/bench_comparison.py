"""Paper Fig. 20: AccelTran-Edge vs edge platforms and AccelTran-Server vs
server platforms (A100 / OPTIMUS / SpAtten / Energon).

Baseline platform numbers are the paper's reported measurements (no
Raspberry Pi / A100 in this container); our accelerators are simulated.
"""
from __future__ import annotations

from repro.core import energy as E
from repro.core.scheduler import EncoderSpec
from repro.core.simulator import Simulator

from .common import banner, save

# Paper-reported baselines, normalised as in Fig. 20 (throughput seq/s,
# energy mJ/seq).  BERT-Tiny for edge, BERT-Base for server.
EDGE_BASELINES = {
    "raspberry-pi-4b": {"throughput_seq_s": 0.143, "energy_mj_per_seq": 25_000.0},
    "intel-ncs-v2": {"throughput_seq_s": 4.1, "energy_mj_per_seq": 450.0},
    "apple-m1-cpu": {"throughput_seq_s": 38.0, "energy_mj_per_seq": 160.0},
    "apple-m1-gpu": {"throughput_seq_s": 120.0, "energy_mj_per_seq": 85.0},
}
SERVER_BASELINES = {
    "a100": {"throughput_seq_s": 570.0, "energy_mj_per_seq": 620.0},
    "optimus": {"throughput_rel_a100": 4.9, "energy_rel_a100": 1 / 310.0},
    "spatten": {"throughput_rel_a100": 9.0, "energy_rel_a100": 1 / 950.0},
    "energon": {"throughput_rel_a100": 11.0, "energy_rel_a100": 1 / 2928.0},
}


def run(quick: bool = False) -> dict:
    banner("Fig. 20: platform comparison")
    edge = Simulator(E.ACCELTRAN_EDGE).run_encoder(
        EncoderSpec.bert_tiny(), batch=4, weight_density=0.5, act_density=0.5
    )
    server = Simulator(E.ACCELTRAN_SERVER).run_encoder(
        EncoderSpec.bert_base(), batch=32, weight_density=0.5, act_density=0.5
    )
    pi = EDGE_BASELINES["raspberry-pi-4b"]
    a100 = SERVER_BASELINES["a100"]
    energon_thr = a100["throughput_seq_s"] * SERVER_BASELINES["energon"]["throughput_rel_a100"]
    energon_e = a100["energy_mj_per_seq"] * SERVER_BASELINES["energon"]["energy_rel_a100"] * 2928 / 2928
    payload = {
        "edge": {
            "acceltran_edge": {
                "throughput_seq_s": edge.throughput_seq_s,
                "energy_mj_per_seq": edge.energy_per_seq_j * 1e3,
            },
            "baselines": EDGE_BASELINES,
            "speedup_vs_raspberry_pi": edge.throughput_seq_s / pi["throughput_seq_s"],
            "energy_gain_vs_raspberry_pi": pi["energy_mj_per_seq"] / (edge.energy_per_seq_j * 1e3),
            "paper_claims": {"speedup": 330_578, "energy_gain": 93_300},
        },
        "server": {
            "acceltran_server": {
                "throughput_seq_s": server.throughput_seq_s,
                "energy_mj_per_seq": server.energy_per_seq_j * 1e3,
            },
            "baselines": SERVER_BASELINES,
            "speedup_vs_a100": server.throughput_seq_s / a100["throughput_seq_s"],
            "paper_claims": {"speedup_vs_a100": 63, "speedup_vs_energon": 5.73, "energy_gain_vs_energon": 3.69},
        },
    }
    e = payload["edge"]
    s = payload["server"]
    print(f"  Edge  vs Raspberry Pi: {e['speedup_vs_raspberry_pi']:.0f}x thr (paper 330,578x), "
          f"{e['energy_gain_vs_raspberry_pi']:.0f}x energy (paper 93,300x)")
    print(f"  Server vs A100: {s['speedup_vs_a100']:.1f}x thr (paper 63x)")
    save("comparison", payload)
    return payload


if __name__ == "__main__":
    run()
