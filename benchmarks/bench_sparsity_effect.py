"""Paper Fig. 19: runtime sparsity knob — throughput/energy vs net sparsity
for BERT-Tiny on AccelTran-Edge (DynaTran's dynamic accuracy/perf trade)."""
from __future__ import annotations

from repro.core import energy as E
from repro.core.scheduler import EncoderSpec
from repro.core.simulator import Simulator

from .common import banner, save


def run(quick: bool = False) -> dict:
    banner("Fig. 19: sparsity -> throughput/energy (Edge)")
    spec = EncoderSpec.bert_tiny()
    sim = Simulator(E.ACCELTRAN_EDGE)
    rows = []
    for act_density in (0.70, 0.66, 0.62, 0.58):
        # net sparsity with 50% weight sparsity: 1 - 0.5*(d_w + d_a) approx
        res = sim.run_encoder(spec, batch=4, weight_density=0.5, act_density=act_density)
        net = 1.0 - (0.5 + act_density) / 2
        rows.append(
            {
                "act_density": act_density,
                "net_sparsity": net,
                "throughput_seq_s": res.throughput_seq_s,
                "energy_per_seq_mj": res.energy_per_seq_j * 1e3,
            }
        )
        print(
            f"  net_sparsity={net:.2f}: thr={res.throughput_seq_s:9.1f} seq/s "
            f"E={res.energy_per_seq_j*1e3:.4f} mJ/seq"
        )
    thr = [r["throughput_seq_s"] for r in rows]
    en = [r["energy_per_seq_mj"] for r in rows]
    payload = {
        "rows": rows,
        "throughput_gain": thr[-1] / thr[0],
        "energy_drop": 1 - en[-1] / en[0],
    }
    print(f"  30->34% net sparsity: +{(payload['throughput_gain']-1)*100:.1f}% thr, -{payload['energy_drop']*100:.1f}% energy (paper: +5%, -2%)")
    save("sparsity_effect", payload)
    return payload


if __name__ == "__main__":
    run()
