"""Paper Fig. 16: compute/memory stalls vs number of PEs and buffer size
(design-space exploration around the AccelTran-Edge point)."""
from __future__ import annotations

import dataclasses

from repro.core import energy as E
from repro.core.scheduler import EncoderSpec
from repro.core.simulator import Simulator

from .common import banner, save


def run(quick: bool = False) -> dict:
    banner("Fig. 16: stalls vs hardware resources (Edge DSE)")
    spec = EncoderSpec.bert_tiny()
    pes_sweep = [32, 64, 128] if quick else [32, 64, 128, 256]
    buf_sweep = [10, 13, 16]  # net MB at the paper's 4:8:1 ratio
    rows = []
    for pes in pes_sweep:
        for net_mb in buf_sweep:
            a, w, m = 4 * net_mb / 13, 8 * net_mb / 13, 1 * net_mb / 13
            cfg = dataclasses.replace(
                E.ACCELTRAN_EDGE, pes=pes, act_buffer_mb=a, weight_buffer_mb=w, mask_buffer_mb=m
            )
            res = Simulator(cfg).run_encoder(spec, batch=4, weight_density=0.5, act_density=0.5)
            rows.append(
                {
                    "pes": pes, "net_buffer_mb": net_mb,
                    "compute_stalls": res.compute_stalls, "memory_stalls": res.memory_stalls,
                    "cycles": res.cycles,
                }
            )
            print(
                f"  pes={pes:4d} buf={net_mb:3d}MB: compute_stalls={res.compute_stalls:6d} "
                f"memory_stalls={res.memory_stalls:5d} cycles={res.cycles:9.0f}"
            )
    save("stalls", {"rows": rows, "chosen_point": {"pes": 64, "net_buffer_mb": 13}})
    return {"rows": rows}


if __name__ == "__main__":
    run()
