"""Paper Fig. 13: pruning-operation throughput, DynaTran vs top-k.

DynaTran is a single fused compare; top-k sorts/selects per row (the paper
measures up to 96x on GPU, 5.35x on CPU).  We measure wall-clock on this
host (CPU backend) for BERT-Tiny- and BERT-Mini-sized activation stacks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dynatran import prune_
from repro.core.topk import topk_prune

from .common import banner, save, timeit


CASES = {
    # [B*H, S, S] attention-score stacks (the tensors both methods target)
    "bert-tiny-like": (2 * 4, 128, 128),
    "bert-mini-like": (4 * 8, 128, 128),
}


def run(quick: bool = False) -> dict:
    banner("Fig. 13: pruning throughput DynaTran vs top-k")
    rows = {}
    dyn = jax.jit(lambda x: prune_(x, 0.01))
    top = jax.jit(lambda x: topk_prune(x, 32)[0])
    for name, shape in CASES.items():
        x = jax.random.normal(jax.random.PRNGKey(0), shape)
        t_dyn = timeit(dyn, x, repeat=3 if quick else 10)
        t_top = timeit(top, x, repeat=3 if quick else 10)
        rows[name] = {
            "dynatran_us": t_dyn * 1e6,
            "topk_us": t_top * 1e6,
            "speedup": t_top / t_dyn,
        }
        print(f"  {name}: dynatran {t_dyn*1e6:8.1f}us  topk {t_top*1e6:8.1f}us  -> {t_top/t_dyn:5.2f}x")
    save("prune_throughput", rows)
    return rows


if __name__ == "__main__":
    run()
