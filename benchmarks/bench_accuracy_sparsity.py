"""Paper Figs. 11/12/14: accuracy & activation sparsity vs pruning
hyper-parameter, DynaTran vs top-k, with and without weight pruning.

Offline stand-in for SST-2 (no datasets in the container): a synthetic
two-class token-distribution task + a BERT-Tiny-family encoder trained for a
few hundred steps.  We reproduce the paper's *relative* claims:

  (a) DynaTran reaches >= top-k accuracy at matched activation sparsity,
  (b) DynaTran reaches ~1.2x the sparsity of top-k at iso-accuracy,
  (c) one-shot WP costs accuracy for marginal net-sparsity gain (Fig. 14).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import KernelPolicy
from repro.core import dynatran as dt
from repro.data.pipeline import ClsDataConfig, ClassificationBatches
from repro.models import bert

from .common import banner, save


def _train_classifier(cfg, data, steps=400, lr=1e-3, seed=0):
    from repro.optim import adamw

    params = bert.init_params(jax.random.PRNGKey(seed), cfg)
    ocfg = adamw.OptimizerConfig(lr=lr, warmup_steps=20, total_steps=steps, weight_decay=0.0)
    state = adamw.init_state(params, ocfg)

    def loss_fn(params, tokens, labels):
        logits = bert.forward(params, cfg, tokens)
        onehot = jax.nn.one_hot(labels, logits.shape[-1])
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    @jax.jit
    def step(params, state, tokens, labels):
        l, g = jax.value_and_grad(loss_fn)(params, tokens, labels)
        params, state, _ = adamw.apply_updates(params, g, state, ocfg)
        return params, state, l

    for s in range(steps):
        b = data.batch(s)
        params, state, l = step(params, state, jnp.asarray(b["tokens"]), jnp.asarray(b["labels"]))
    return params


def _accuracy(params, cfg, eval_set, policy=None):
    correct = total = 0
    for b in eval_set:
        logits = bert.forward(params, cfg, jnp.asarray(b["tokens"]), policy=policy)
        pred = np.asarray(jnp.argmax(logits, -1))
        correct += int((pred == b["labels"]).sum())
        total += len(b["labels"])
    return correct / total


def _act_sparsity(params, cfg, eval_set, tau):
    """Mean post-prune sparsity across DynaTran sites (the paper's 'net
    activation sparsity')."""
    sites = bert.capture_activations(params, cfg, jnp.asarray(eval_set[0]["tokens"]))
    vals = []
    for name, tensors in sites.items():
        for t in tensors:
            vals.append(float(dt.sparsity(dt.prune_(t, tau))))
    return float(np.mean(vals))


def run(quick: bool = False) -> dict:
    banner("Figs. 11/12/14: DynaTran vs top-k accuracy/sparsity")
    cfg = bert.bert_config("bert-tiny")
    data = ClassificationBatches(ClsDataConfig(vocab=cfg.vocab, seq_len=48, batch=32, signal=100.0))
    params = _train_classifier(cfg, data, steps=100 if quick else 400)
    eval_set = data.eval_set(2 if quick else 6)

    base_acc = _accuracy(params, cfg, eval_set)

    taus = [0.0, 0.005, 0.01, 0.02, 0.04, 0.06, 0.1] if not quick else [0.0, 0.02, 0.1]
    dyn_rows = []
    for tau in taus:
        sp = dt.SparsityConfig(mode="dynatran", sites=("attn_probs", "ffn_act", "attn_out"))
        t = {"attn_probs": tau, "ffn_act": tau, "attn_out": tau}
        acc = _accuracy(params, cfg, eval_set, policy=KernelPolicy.from_config(sp, t))
        rho = _act_sparsity(params, cfg, eval_set, tau)
        dyn_rows.append({"tau": tau, "accuracy": acc, "act_sparsity": rho})

    ks = [64, 32, 16, 8, 4, 2] if not quick else [32, 4]
    topk_rows = []
    for k in ks:
        sp = dt.SparsityConfig(mode="topk", topk_k=k)
        acc = _accuracy(params, cfg, eval_set, sparsity=sp)
        # net activation sparsity of top-k: fraction of pruned attn probs only
        rho_attn = max(0.0, 1.0 - k / 48)
        # attn probs are ~1/3 of prunable activation volume in this model
        topk_rows.append({"k": k, "accuracy": acc, "act_sparsity": rho_attn / 3})

    # Fig. 14: one-shot WP (weight pruning) vs no WP
    wp_rows = []
    for tau_w in [0.0, 0.02, 0.05]:
        p2, stats = dt.weight_prune(params, tau_w)
        acc = _accuracy(p2, cfg, eval_set)
        wp_rows.append({"tau_w": tau_w, "accuracy": acc, **stats})

    # headline comparisons
    best_topk_acc = max(r["accuracy"] for r in topk_rows)
    dyn_at_or_above = [r for r in dyn_rows if r["accuracy"] >= best_topk_acc - 1e-9]
    max_dyn_rho = max((r["act_sparsity"] for r in dyn_at_or_above), default=0.0)
    max_topk_rho = max(r["act_sparsity"] for r in topk_rows if r["accuracy"] >= best_topk_acc - 1e-9)
    payload = {
        "baseline_accuracy": base_acc,
        "dynatran": dyn_rows,
        "topk": topk_rows,
        "weight_pruning": wp_rows,
        "dynatran_sparsity_at_topk_best_acc": max_dyn_rho,
        "topk_sparsity_at_best_acc": max_topk_rho,
        "sparsity_ratio_dyn_over_topk": (max_dyn_rho / max_topk_rho) if max_topk_rho else None,
    }
    for r in dyn_rows:
        print(f"  dynatran tau={r['tau']:<6} acc={r['accuracy']:.3f} rho={r['act_sparsity']:.3f}")
    for r in topk_rows:
        print(f"  topk     k={r['k']:<8} acc={r['accuracy']:.3f} rho~{r['act_sparsity']:.3f}")
    for r in wp_rows:
        print(f"  WP       tau_w={r['tau_w']:<5} acc={r['accuracy']:.3f} wsp={r['weight_sparsity']:.3f}")
    save("accuracy_sparsity", payload)
    return payload


if __name__ == "__main__":
    run()
