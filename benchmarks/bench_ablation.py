"""Paper Table IV: ablation of BERT-Tiny inference on AccelTran-Server.

Rows: full config / w/o DynaTran / w/o MP / w/o sparsity-aware modules /
w/o monolithic-3D RRAM.
"""
from __future__ import annotations

import dataclasses

from repro.core import energy as E
from repro.core.scheduler import EncoderSpec
from repro.core.simulator import Simulator

from .common import banner, save

PAPER = {
    "AccelTran-Server": (172_180, 0.1396, 24.04),
    "w/o DynaTran": (93_333, 0.1503, 14.03),
    "w/o MP": (163_484, 0.2009, 32.85),
    "w/o Sparsity-aware modules": (90_410, 0.2701, 24.43),
    "w/o Monolithic-3D RRAM": (88_736, 0.1737, 15.42),
}


def run(quick: bool = False) -> dict:
    banner("Table IV: AccelTran-Server ablation (BERT-Tiny)")
    spec = EncoderSpec.bert_tiny()
    dram = dataclasses.replace(
        E.ACCELTRAN_SERVER, name="server-dram", mem_bandwidth_gbps=25.6, mem_kind="lpddr3"
    )
    runs = {
        "AccelTran-Server": (Simulator(E.ACCELTRAN_SERVER), dict(weight_density=0.5, act_density=0.5)),
        "w/o DynaTran": (Simulator(E.ACCELTRAN_SERVER), dict(weight_density=0.5, act_density=1.0)),
        "w/o MP": (Simulator(E.ACCELTRAN_SERVER), dict(weight_density=1.0, act_density=0.5)),
        "w/o Sparsity-aware modules": (
            Simulator(E.ACCELTRAN_SERVER, sparsity_modules=False),
            dict(weight_density=0.5, act_density=0.5),
        ),
        "w/o Monolithic-3D RRAM": (
            Simulator(dram),
            dict(weight_density=0.5, act_density=0.5, embedding_resident=False),
        ),
    }
    rows = {}
    for name, (sim, kw) in runs.items():
        res = sim.run_encoder(spec, batch=32, **kw)
        p_thr, p_e, p_w = PAPER[name]
        rows[name] = {
            "throughput_seq_s": res.throughput_seq_s,
            "energy_mj_per_seq": res.energy_per_seq_j * 1e3,
            "net_power_w": res.avg_power_w,
            "paper_throughput": p_thr,
            "paper_energy_mj": p_e,
            "paper_power_w": p_w,
            "throughput_ratio_vs_paper": res.throughput_seq_s / p_thr,
        }
        print(
            f"  {name:28s} thr={res.throughput_seq_s:9.0f} (paper {p_thr:7d}) "
            f"E={res.energy_per_seq_j*1e3:.4f} (paper {p_e:.4f}) P={res.avg_power_w:6.2f}W (paper {p_w:.2f})"
        )
    save("ablation", rows)
    return rows


if __name__ == "__main__":
    run()
