"""§Roofline report: aggregate the dry-run JSONs (experiments/dryrun/) into
the per-(arch x shape x mesh) roofline table and print it."""
from __future__ import annotations

import glob
import json
import os

from .common import banner, save

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(quick: bool = False) -> dict:
    banner("§Roofline: per-cell table from the dry-run artifacts")
    cells = load_cells()
    ok = [c for c in cells if "bottleneck" in c]
    skipped = [c for c in cells if "skipped" in c]
    failed = [c for c in cells if "error" in c]
    hdr = f"  {'arch':15s} {'shape':12s} {'mesh':6s} {'strategy':7s} {'t_comp':>8s} {'t_mem':>8s} {'t_coll':>8s} {'bound':>10s} {'useful':>7s} {'roofline':>8s}"
    print(hdr)
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        print(
            f"  {c['arch']:15s} {c['shape']:12s} {c['mesh']:6s} {c.get('strategy','?'):7s} "
            f"{c['t_compute_s']:8.3f} {c['t_memory_s']:8.3f} {c['t_collective_s']:8.3f} "
            f"{c['bottleneck']:>10s} {c['useful_flops_ratio']:7.3f} {c['roofline_fraction']:8.3f}"
        )
    print(f"  ok={len(ok)} skipped(policy)={len(skipped)} failed={len(failed)}")
    payload = {
        "cells": ok,
        "skipped": [{k: c[k] for k in ("arch", "shape", "mesh", "skipped")} for c in skipped],
        "failed": [{k: c.get(k) for k in ("arch", "shape", "mesh", "error")} for c in failed],
    }
    save("roofline", payload)
    return payload


if __name__ == "__main__":
    run()
