"""End-to-end driver: train a ~100M-param decoder LM for a few hundred steps
with checkpoint/restart, then compare dense vs DynaTran-sparsified eval —
the paper's workflow (weight-prune -> profile curves -> dynamic inference)
on the training substrate.

    PYTHONPATH=src python examples/train_bert_dynatran.py [--steps 300] [--small]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import KernelPolicy
from repro.configs.base import ModelConfig
from repro.core import dynatran as dt
from repro.data.pipeline import LMBatches, LMDataConfig
from repro.models import zoo
from repro.optim import OptimizerConfig
from repro.train.loop import train


def lm_100m() -> ModelConfig:
    # ~100M params: 12L x 768 (GPT-2-small-scale), qwen-style blocks
    return ModelConfig(
        name="lm-100m", family="dense", layers=12, d_model=768, heads=12, kv_heads=12,
        d_ff=2048, vocab=8192, remat="none",
    )


def lm_small() -> ModelConfig:
    return ModelConfig(
        name="lm-small", family="dense", layers=4, d_model=256, heads=4, kv_heads=4,
        d_ff=512, vocab=2048, remat="none",
    )


def eval_ce(params, cfg, data, taus=None, steps=4, offset=50_000):
    policy = KernelPolicy.from_config(cfg.sparsity, taus)
    tot = 0.0
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(offset + i).items()}
        loss, _ = zoo.loss_fn(params, cfg, b, policy=policy)
        tot += float(loss)
    return tot / steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true", help="tiny model (fast CI)")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt_example")
    args = ap.parse_args()

    cfg = lm_small() if args.small else lm_100m()
    n_params = cfg.param_count() / 1e6
    print(f"[example] training {cfg.name} ({n_params:.1f}M params) for {args.steps} steps")
    data = LMBatches(LMDataConfig(vocab=cfg.vocab, seq_len=128, batch=8, branching=4))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)

    t0 = time.time()
    state, history = train(
        cfg, ocfg, data, steps=args.steps,
        checkpoint_dir=args.checkpoint_dir, checkpoint_every=max(50, args.steps // 4),
    )
    print(f"[example] trained in {time.time()-t0:.0f}s; loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")

    # --- the paper's pipeline on the trained model ----------------------
    # 1. one-shot weight pruning (the paper's WP / stand-in for MP ckpts)
    wp_params, stats = dt.weight_prune(state.params, tau=0.01)
    print(f"[example] weight pruning: {stats['weight_sparsity']*100:.1f}% weight sparsity")

    # 2. profile per-site transfer curves on calibration batches
    calib = [jnp.asarray(data.batch(90_000 + i)["tokens"]) for i in range(2)]
    h_samples = []
    for toks in calib:
        logits, _ = zoo.forward(state.params, cfg, toks)
        h_samples.append(logits)
    curve = dt.profile_curve([np.asarray(h) for h in h_samples])
    calc = dt.ThresholdCalculator({s: curve for s in dt.SITES})

    # 3. dynamic inference at increasing sparsity: CE vs rho (Fig. 19 trade)
    dense_ce = eval_ce(state.params, cfg, data)
    print(f"[example] dense eval CE: {dense_ce:.4f}")
    sp_base = dataclasses.replace(cfg.sparsity, mode="dynatran")
    for rho in (0.25, 0.5):
        cfg_sp = dataclasses.replace(cfg, sparsity=dataclasses.replace(sp_base, target_rho=rho))
        taus = calc.taus(cfg_sp.sparsity)
        ce = eval_ce(state.params, cfg_sp, data, taus)
        print(f"[example] dynatran rho={rho}: eval CE {ce:.4f} (delta {ce-dense_ce:+.4f})")

    # 4. resume-from-checkpoint smoke (fault-tolerance path)
    state2, _ = train(cfg, ocfg, data, steps=args.steps, checkpoint_dir=args.checkpoint_dir)
    print(f"[example] resume check: restored step == {state2.step}")


if __name__ == "__main__":
    main()
