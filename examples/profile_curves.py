"""Offline DynaTran profiling: capture per-site activations from a BERT
encoder on calibration batches and emit the sparsity<->threshold transfer
curves (the contents of the DynaTran module's internal register).

    PYTHONPATH=src python examples/profile_curves.py
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dynatran as dt
from repro.data.pipeline import ClsDataConfig, ClassificationBatches
from repro.models import bert


def main():
    cfg = bert.bert_config("bert-tiny")
    params = bert.init_params(jax.random.PRNGKey(0), cfg)
    data = ClassificationBatches(ClsDataConfig(vocab=cfg.vocab, seq_len=64, batch=16))

    site_samples = {s: [] for s in ("ffn_act", "attn_probs", "attn_out")}
    for i in range(3):
        toks = jnp.asarray(data.batch(i)["tokens"])
        sites = bert.capture_activations(params, cfg, toks)
        for name, tensors in sites.items():
            site_samples[name].extend(np.asarray(t) for t in tensors)

    out = {}
    calc_curves = {}
    for name, samples in site_samples.items():
        curve = dt.profile_curve(samples)
        calc_curves[name] = curve
        out[name] = {"taus": np.asarray(curve.taus).tolist(), "rhos": np.asarray(curve.rhos).tolist()}
        t50 = float(curve.tau_for_rho(0.5))
        print(f"[profile] {name:11s}: tau(rho=0.5) = {t50:.5f}, rho(tau=0.01) = {float(curve.rho_for_tau(0.01)):.3f}")

    path = "/tmp/dynatran_curves.json"
    with open(path, "w") as f:
        json.dump(out, f)
    print(f"[profile] curves written to {path} ({os.path.getsize(path)} bytes — the "
          f"'internal register' footprint)")

    # verify the runtime lookup hits its target on fresh data
    calc = dt.ThresholdCalculator(calc_curves)
    toks = jnp.asarray(data.batch(100)["tokens"])
    fresh = bert.capture_activations(params, cfg, toks)
    for name in site_samples:
        tau = calc.tau(name, 0.5)
        rhos = [float(dt.sparsity(dt.prune_(t, tau))) for t in fresh[name]]
        print(f"[profile] {name:11s}: target rho=0.50 -> measured {np.mean(rhos):.3f}")


if __name__ == "__main__":
    main()
