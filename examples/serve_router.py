"""Multi-replica serving example: the router's whole degradation story
on a 2-replica fleet.

1. Prefix-affinity routing — a shared system prompt warms both replicas'
   page caches; the next wave is hashed onto whichever replica already
   holds the longest registered prefix, so warm requests land on warm
   pages (affinity hit-rate printed).
2. Three-tenant burst — "free" floods the router while "pro" (weight 4)
   and "batch" trickle.  Weighted fair queuing keeps pro ahead of the
   flood, and the per-tenant token bucket throttles ONLY the flooder
   (throttling defers requests — nothing is dropped).
3. The SLO ladder — backlog pressure drives the fleet's rho up the
   quantized rungs (every retarget announced to both replicas) and the
   router only starts shedding once the TOP rung is reached: accuracy is
   traded first, capacity last.  The rho trace and first-shed tick
   printed at the end prove the ordering.

    PYTHONPATH=src python examples/serve_router.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.dynatran import SparsityConfig
from repro.models import zoo
from repro.router import Router, RouterPolicy, render_prometheus
from repro.serve.engine import ContinuousServeConfig, ContinuousServeEngine


def build_fleet(cfg, params, rng):
    warm_prompt = rng.integers(1, cfg.vocab, size=8).tolist()

    def make():
        eng = ContinuousServeEngine(
            cfg, params,
            ContinuousServeConfig(slots=2, max_len=128, page_size=8, prefill_chunk=8),
        )
        # pre-warm the jit OUTSIDE the router: compile time would otherwise
        # read as a multi-second p99 overrun and spike the SLO ladder (every
        # rung change flushes the fleet's prefix caches)
        eng.generate([warm_prompt], max_new_tokens=2)
        eng.drop_prefix_cache()
        eng.clear_history()
        return eng

    return Router(
        [make(), make()],
        RouterPolicy(
            replica_depth_hw=2,   # hold excess in the router, not replica queues
            queue_cap=8,          # backlog above which a SATURATED ladder sheds
            tenant_rate=200.0,    # tokens/s per-tenant bucket refill
            tenant_burst=150.0,   # bucket capacity — the flood drains it fast
            depth_lo=2, depth_hi=10, rho_ema=0.7,
            slo_p99_ms=500.0,
        ),
        weights={"free": 1.0, "pro": 4.0, "batch": 1.0},
    )


def affinity_wave(router, rng, vocab):
    system = rng.integers(1, vocab, size=24).tolist()  # 3 shared pages
    warm = [
        router.submit(system + rng.integers(1, vocab, size=4).tolist(), max_new_tokens=6)
        for _ in range(2)
    ]
    router.run_until_complete()  # both replicas now hold the system pages
    wave = [
        router.submit(system + rng.integers(1, vocab, size=4).tolist(), max_new_tokens=6)
        for _ in range(4)
    ]
    router.run_until_complete()
    m = router.metrics()
    print(
        f"[router] affinity: {len(warm)} warm + {len(wave)} wave requests -> "
        f"{m['affinity_hits']} hits / {m['affinity_misses']} misses "
        f"(hit rate {m['affinity_hit_rate']:.2f}) — warm requests land on warm pages"
    )
    return system


def tenant_burst(router, rng, vocab, system):
    # "free" floods; "pro" and "batch" trickle.  Interleave the submits so
    # fair queuing (not submission order) decides who decodes first.
    t0 = router._tick  # normalize the printed trace to this burst
    handles = []
    for i in range(18):
        handles.append((
            "free",
            router.submit(system + rng.integers(1, vocab, size=4).tolist(),
                          tenant="free", max_new_tokens=8),
        ))
        if i % 3 == 0:
            handles.append((
                "pro",
                router.submit(system + rng.integers(1, vocab, size=4).tolist(),
                              tenant="pro", max_new_tokens=8),
            ))
        if i % 4 == 0:
            handles.append((
                "batch",
                router.submit(system + rng.integers(1, vocab, size=4).tolist(),
                              tenant="batch", max_new_tokens=8),
            ))

    tick, last = 0, None
    while router.backlog or router.in_flight:
        router.step()
        tick += 1
        m = router.metrics()
        key = (m["backlog"], m["rho"], m["sheds"])
        if key != last:  # print on change, not per tick
            last = key
            depth = {k: v for k, v in m["tenant_depth"].items() if k != "default"}
            print(
                f"  tick {tick:4d}: backlog {m['backlog']:2d} | rho {m['rho']:.2f} | "
                f"sheds {m['sheds']:2d} | throttles {m['throttles']:2d} | "
                f"tenant depth {depth}"
            )
        if router.backlog and not router.in_flight:
            # every queued tenant is bucket-throttled: the fleet is idle
            # until a bucket refills, so wait instead of spinning
            time.sleep(0.01)

    m = router.metrics()
    for name in ("free", "pro", "batch"):
        t = router.fair.tenants[name]
        completed = sum(1 for tn, h in handles if tn == name and h.done and not h.shed)
        shed = sum(1 for tn, h in handles if tn == name and h.shed)
        print(
            f"[router] tenant {name:6s}: submitted {t.submitted:2d}, "
            f"completed {completed:2d}, shed {shed:2d}, "
            f"throttled {t.throttles:2d} times"
        )
    flood = router.fair.tenants["free"].throttles
    calm = router.fair.tenants["pro"].throttles + router.fair.tenants["batch"].throttles
    print(f"[router] only the flooder pays: free throttled {flood}x, pro+batch {calm}x")
    shed_msg = (
        f"{m['sheds']} sheds, first at tick {m['first_shed_tick'] - t0} — "
        "rho saturated BEFORE the first rejection"
        if m["sheds"]
        else "no sheds — the ladder absorbed the burst (rejection is the LAST resort)"
    )
    trace = [(t - t0, rho) for t, rho in m["rho_trace"] if t >= t0]
    print(f"[router] degradation ladder: rho trace {trace} | {shed_msg}")
    return m


def main():
    cfg = dataclasses.replace(
        get_smoke("qwen3-4b"),
        sparsity=SparsityConfig(mode="dynatran", target_rho=0.0),
    )
    params = zoo.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    router = build_fleet(cfg, params, rng)
    system = affinity_wave(router, rng, cfg.vocab)
    m = tenant_burst(router, rng, cfg.vocab, system)

    print("\n[router] Prometheus endpoint (what --metrics serves):\n")
    text = render_prometheus(m)
    head = [ln for ln in text.splitlines() if "replica" not in ln][:18]
    print("\n".join(head))
    print(f"  ... plus per-replica families ({text.count(chr(10)) + 1} lines total)")


if __name__ == "__main__":
    main()
