"""Serving example: the DynaTran runtime knob and the request-lifecycle
API, three ways.

1. Fixed knob on the slot-granularity baseline — trade accuracy for
   throughput at serve time without recompilation (paper Fig. 19).
2. Closed loop on the paged-KV continuous-batching engine — a burst of
   requests deepens the queue, the RhoController raises target_rho along
   the profiled transfer curves, and rho relaxes back once the burst
   drains.
3. Request lifecycle — per-request SamplingParams (temperature / top-k /
   top-p / seed enter the jitted step as runtime per-row scalars), token
   streaming + cancellation, and refcounted shared-prefix page caching
   (requests with the same system prompt link the same physical KV pages,
   copy-on-write).
4. Tile skipping under load — the tiled DynaTran datapath
   (``tile_skip=True``): the RhoController deepens target_rho with the
   queue, each tick re-resolves the KernelPolicy taus from the profiled
   transfer curves (runtime pytree leaves — the knob never recompiles),
   scatter-time occupancy bits go dead, and the skipping kernels read
   fewer KV pages per token.  Watch occupancy fall and tokens/s rise as
   the burst deepens.

    PYTHONPATH=src python examples/serve_dynamic.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.dynatran import SparsityConfig, ThresholdCalculator, TransferCurve
from repro.models import zoo
from repro.serve.engine import ContinuousServeConfig, ContinuousServeEngine, ServeConfig, ServeEngine
from repro.serve.sampling import SamplingParams


def fixed_knob_baseline(cfg, params, prompts):
    for rho in (None, 0.3, 0.6):
        engine = ServeEngine(cfg, params, ServeConfig(slots=4, max_len=128, target_rho=rho))
        t0 = time.perf_counter()
        outs = engine.generate(prompts, max_new_tokens=16)
        dt_s = time.perf_counter() - t0
        label = "dense-profile" if rho is None else f"rho={rho}"
        print(f"[serve] {label:14s}: {sum(len(o) for o in outs)/dt_s:7.1f} tok/s, first out {outs[0][:6]}")


def adaptive_rho_burst(cfg, params, prompts):
    engine = ContinuousServeEngine(
        cfg,
        params,
        ContinuousServeConfig(
            slots=4, max_len=128, page_size=16, prefill_chunk=8,
            adaptive_rho=True, rho_max=0.6, depth_lo=1, depth_hi=8,
        ),
    )
    for p in prompts * 4:  # burst: queue depth >> slots
        engine.submit(p, max_new_tokens=12)
    trace = []
    while engine.sched.queue or engine.sched.active:
        engine.step()
        trace.append((engine.sched.queue_depth, engine.current_rho))
    m = engine.metrics()
    peak = max(r for _, r in trace)
    print(
        f"[serve] continuous burst: {m['tokens']} tokens, p50 {m['p50_latency_s']:.3f}s "
        f"p99 {m['p99_latency_s']:.3f}s | rho peaked at {peak:.2f} under load, "
        f"relaxed to {trace[-1][1]:.2f} when drained"
    )


def request_lifecycle(cfg, params):
    """Streaming, cancellation, per-request sampling, shared prefixes."""
    rng = np.random.default_rng(7)
    system = rng.integers(1, cfg.vocab, size=16).tolist()  # shared "system prompt"
    engine = ContinuousServeEngine(
        cfg, params, ContinuousServeConfig(slots=4, max_len=128, page_size=8, prefill_chunk=8)
    )
    # warm the prefix cache, then fan out same-prefix requests with
    # DIFFERENT per-request sampling policies in one decode batch
    engine.generate([system + rng.integers(1, cfg.vocab, size=4).tolist()], max_new_tokens=8)
    handles = [
        engine.submit(
            system + rng.integers(1, cfg.vocab, size=4).tolist(),
            sampling=SamplingParams(temperature=t, top_k=40, seed=i, max_new_tokens=12),
        )
        for i, t in enumerate((0.0, 0.7, 1.0, 1.3))
    ]
    victim = engine.submit(system + rng.integers(1, cfg.vocab, size=4).tolist(), max_new_tokens=12)

    stream = []
    for tok in handles[1].tokens():  # drives engine.step() under the hood
        stream.append(tok)
        if len(stream) == 4:
            victim.cancel()  # frees its slot + page links immediately
    engine.run_until_complete()
    m = engine.metrics()
    pc = m["prefix_cache"]
    print(f"[serve] streamed 12 tokens from a temperature=0.7 request: {stream[:6]}...")
    print(
        f"[serve] lifecycle: greedy row {handles[0].generated[:4]}, hot row {handles[3].generated[:4]} "
        f"decoded in ONE batch; cancelled request freed after {len(victim.generated)} tokens"
    )
    print(
        f"[serve] prefix cache: hit rate {pc['hit_rate']:.2f}, {pc['pages_shared']} page links shared, "
        f"burst peak {m['peak_pages_in_use']} pages in use"
    )


def tile_skip_under_load(cfg, params):
    """The closed rho loop driving the TILED datapath: occupancy bits are
    marked at scatter time from the tick's tau_kv, and the skipping kernels
    drop all-dead pages — so a deeper queue buys throughput, not just
    cheaper activations."""
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab, size=48).tolist() for _ in range(12)]

    # profile the "kv" transfer curve off a short legacy-datapath run: tau at
    # rho r is the r-quantile of the cached per-position max|k|, so the
    # controller's rho maps onto a real dead fraction of the cache
    probe = ContinuousServeEngine(
        cfg, params, ContinuousServeConfig(slots=1, max_len=128, page_size=4, prefill_chunk=8)
    )
    probe.generate(prompts[:1], max_new_tokens=4)
    mags = np.concatenate([
        np.abs(np.asarray(leaf)).max(axis=(-2, -1)).ravel()
        for leaf in jax.tree_util.tree_leaves(probe.pools.k)
    ])
    rhos = np.linspace(0.0, 1.0, 9)
    taus_kv = np.quantile(mags[mags > 0], rhos)  # unwritten slots are zero
    taus_kv[0] = 0.0
    calc = ThresholdCalculator({
        "kv": TransferCurve(taus=jnp.asarray(taus_kv, jnp.float32), rhos=jnp.asarray(rhos, jnp.float32)),
        "ffn_act": TransferCurve(taus=jnp.linspace(0.0, 0.2, 9), rhos=jnp.asarray(rhos, jnp.float32)),
        "attn_out": TransferCurve(taus=jnp.linspace(0.0, 0.05, 9), rhos=jnp.asarray(rhos, jnp.float32)),
    })

    engine = ContinuousServeEngine(
        cfg, params,
        ContinuousServeConfig(slots=2, max_len=128, page_size=4, prefill_chunk=8,
                              adaptive_rho=True, rho_max=0.75, depth_lo=1, depth_hi=8,
                              tile_skip=True),
        calculator=calc,
    )
    for p in prompts:
        engine.submit(p, max_new_tokens=12)
    print(f"[serve] tile-skip burst: {len(prompts)} requests over 2 slots, rho_max 0.75")
    tick, last_toks, last_t = 0, 0, time.perf_counter()
    while engine.sched.queue or engine.sched.active:
        engine.step()
        tick += 1
        if tick % 10 == 0 or not (engine.sched.queue or engine.sched.active):
            m = engine.metrics()
            now = time.perf_counter()
            rate = (m["tokens"] - last_toks) / max(now - last_t, 1e-9)
            last_toks, last_t = m["tokens"], now
            print(
                f"  tick {tick:3d}: queue {m['queue_depth']:2d} | rho {m['rho']:.2f} "
                f"-> tau_kv {np.interp(m['rho'], rhos, taus_kv):.2f} | "
                f"kv occupancy live {m['kv_occupancy_live']:.2f} | {rate:7.1f} tok/s"
            )
    m = engine.metrics()
    print(
        f"[serve] tile-skip burst done: {m['tokens']} tokens, p50 {m['p50_latency_s']:.3f}s "
        f"p99 {m['p99_latency_s']:.3f}s | final kv occupancy {m['kv_occupancy_live']:.2f}"
    )


def main():
    cfg = get_smoke("gemma2-9b")  # reduced gemma-2 family config (CPU-sized)
    cfg = dataclasses.replace(cfg, sparsity=SparsityConfig(mode="dynatran", target_rho=0.3))
    params = zoo.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=12).tolist() for _ in range(4)]

    fixed_knob_baseline(cfg, params, prompts)

    # the paged engine pages sliding-window layers into ring tables, so the
    # continuous demo runs the gemma-2 reduction itself: the "sliding" half
    # of its local/global stack costs ceil(window/P)+1 pages per sequence
    ccfg = dataclasses.replace(cfg, sparsity=SparsityConfig(mode="dynatran", target_rho=0.0))
    adaptive_rho_burst(ccfg, params, prompts)

    # prefix sharing needs an all-full-attention layout (ring pages are
    # per-sequence), so the lifecycle demo runs a dense config
    dense = dataclasses.replace(
        get_smoke("qwen3-4b"), sparsity=SparsityConfig(mode="none", target_rho=0.0)
    )
    dense_params = zoo.init_params(jax.random.PRNGKey(1), dense)
    request_lifecycle(dense, dense_params)

    # the tiled datapath needs the "kv" site opted in (occupancy bits are
    # only written for sites the policy wants)
    sparse = dataclasses.replace(
        dense, sparsity=SparsityConfig(mode="dynatran", sites=("ffn_act", "attn_out", "kv"))
    )
    tile_skip_under_load(sparse, dense_params)


if __name__ == "__main__":
    main()
