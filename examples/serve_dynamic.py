"""Serving example: batched generation with the DynaTran runtime knob —
trade accuracy for throughput *at serve time* without recompilation
(paper Fig. 19's dynamic adjustment).

    PYTHONPATH=src python examples/serve_dynamic.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.dynatran import SparsityConfig
from repro.models import zoo
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    cfg = get_smoke("gemma2-9b")  # reduced gemma-2 family config (CPU-sized)
    cfg = dataclasses.replace(cfg, sparsity=SparsityConfig(mode="dynatran", target_rho=0.3))
    params = zoo.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=12).tolist() for _ in range(4)]

    for rho in (None, 0.3, 0.6):
        engine = ServeEngine(cfg, params, ServeConfig(slots=4, max_len=128, target_rho=rho))
        t0 = time.perf_counter()
        outs = engine.generate(prompts, max_new_tokens=16)
        dt_s = time.perf_counter() - t0
        label = "dense-profile" if rho is None else f"rho={rho}"
        print(f"[serve] {label:14s}: {sum(len(o) for o in outs)/dt_s:7.1f} tok/s, first out {outs[0][:6]}")


if __name__ == "__main__":
    main()
