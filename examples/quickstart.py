"""Quickstart: build an assigned architecture, run DynaTran-sparsified
inference, inspect the sparsity/accuracy knob.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core.policy import KernelPolicy
from repro.configs import get_smoke
from repro.core.dynatran import SparsityConfig, ThresholdCalculator, profile_curve, sparsity
from repro.models import zoo


def main():
    # 1. any assigned arch is one registry call away (reduced config for CPU)
    cfg = get_smoke("qwen3-4b")
    params = zoo.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)

    # 2. dense forward
    logits, _ = zoo.forward(params, cfg, tokens)
    print(f"dense logits: {logits.shape}, top token {int(jnp.argmax(logits[0, -1]))}")

    # 3. profile a DynaTran transfer curve from calibration activations
    #    (the contents of the ASIC's "internal register")
    acts = [jax.random.normal(jax.random.PRNGKey(i), (512, 128)) for i in range(4)]
    curve = profile_curve(acts)
    calc = ThresholdCalculator({s: curve for s in ("ffn_act", "attn_probs", "attn_out", "block_out")})

    # 4. run with runtime activation pruning at a target sparsity
    sp = SparsityConfig(mode="dynatran", target_rho=0.5)
    cfg_sparse = dataclasses.replace(cfg, sparsity=sp)
    taus = calc.taus(sp)
    logits_sp, _ = zoo.forward(
        params, cfg_sparse, tokens,
        policy=KernelPolicy.from_config(cfg_sparse.sparsity, taus),
    )
    drift = float(jnp.mean(jnp.abs(logits_sp - logits)))
    print(f"dynatran rho=0.5: taus={ {k: round(float(v),4) for k,v in taus.items()} }")
    print(f"mean logit drift vs dense: {drift:.4f}")

    # 5. the same knob at serve time (one line per target)
    for rho in (0.25, 0.5, 0.75):
        tau = calc.tau("ffn_act", rho)
        x = jax.random.normal(jax.random.PRNGKey(7), (256, 256))
        got = float(sparsity(jnp.where(jnp.abs(x) >= tau, x, 0)))
        print(f"  target rho={rho:.2f} -> tau={float(tau):.4f} -> measured rho={got:.2f}")


if __name__ == "__main__":
    main()
