"""Docs checker: dead-link/anchor detection + README snippet execution.

CI's docs-check lane runs ``python -m tools.check_docs``, which

1. walks the repo's markdown surface (``README.md`` + ``docs/*.md``) and
   verifies every **relative** link resolves to a real file and every
   ``#anchor`` (same-file or cross-file) matches a real heading under
   GitHub's slug rules — so a renamed heading or moved doc fails CI
   instead of shipping a dead pointer;
2. executes every fenced ``python`` block in ``README.md`` in a
   subprocess — the quickstart snippet is a tested artifact, not prose.

External links (``http://``, ``https://``, ``mailto:``) are not fetched
(CI must not flake on the network), and targets that resolve *outside*
the repo root are skipped — GitHub serves repo-app URLs like the CI
badge's ``../../actions/...`` that have no filesystem counterpart.

Exit status is the number of findings (0 = clean).  ``--no-exec`` skips
snippet execution (link check only, fast).
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from pathlib import Path

# inline markdown links: [text](target) — images share the syntax
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
_FENCE_RE = re.compile(r"^(```+|~~~+)\s*(\S*)\s*$")


def github_slug(heading: str, seen: dict[str, int]) -> str:
    """GitHub's heading→anchor slug: strip markdown emphasis/code marks,
    lowercase, drop punctuation, spaces→hyphens, ``-N`` suffix on dups."""
    text = re.sub(r"[*_`]", "", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linked headings keep the text
    slug = re.sub(r"[^\w\- ]", "", text.lower(), flags=re.UNICODE)
    slug = slug.replace(" ", "-")
    n = seen.get(slug, 0)
    seen[slug] = n + 1
    return slug if n == 0 else f"{slug}-{n}"


def parse_markdown(path: Path) -> tuple[set[str], list[tuple[int, str]], list[tuple[int, str]]]:
    """Return (anchor slugs, [(lineno, link target)], [(lineno, python block)]).

    Links inside fenced code blocks are NOT links (a bash example showing
    markdown syntax must not trip the checker); fenced ``python`` blocks
    are collected verbatim for execution.
    """
    anchors: set[str] = set()
    links: list[tuple[int, str]] = []
    snippets: list[tuple[int, str]] = []
    seen: dict[str, int] = {}
    fence: str | None = None  # the opening fence marker while inside a block
    block_lang, block_lines, block_start = "", [], 0
    for lineno, line in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
        m = _FENCE_RE.match(line)
        if fence is None and m:
            fence, block_lang, block_lines, block_start = m.group(1), m.group(2).lower(), [], lineno
            continue
        if fence is not None:
            if m and m.group(1)[0] == fence[0] and len(m.group(1)) >= len(fence):
                if block_lang == "python":
                    snippets.append((block_start, "\n".join(block_lines)))
                fence = None
            else:
                block_lines.append(line)
            continue
        h = _HEADING_RE.match(line)
        if h:
            anchors.add(github_slug(h.group(2), seen))
        for lm in _LINK_RE.finditer(line):
            links.append((lineno, lm.group(1)))
    return anchors, links, snippets


def check_links(files: list[Path], root: Path) -> list[str]:
    """Dead relative links/anchors across ``files``; returns findings."""
    parsed = {f.resolve(): parse_markdown(f) for f in files}
    findings: list[str] = []
    for f in files:
        f = f.resolve()
        _, links, _ = parsed[f]
        for lineno, target in links:
            where = f"{f.relative_to(root)}:{lineno}"
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, https:, mailto:, ...
                continue
            path_part, _, frag = target.partition("#")
            dest = f if not path_part else (f.parent / path_part).resolve()
            try:
                dest.relative_to(root)
            except ValueError:
                continue  # repo-app URL (e.g. the CI badge) — no file to check
            if not dest.exists():
                findings.append(f"{where}: dead link '{target}' (no such file)")
                continue
            if frag:
                if dest not in parsed:
                    if dest.suffix.lower() in (".md", ".markdown"):
                        parsed[dest] = parse_markdown(dest)
                    else:
                        continue  # fragment into a non-markdown file: not checkable
                if frag.lower() not in parsed[dest][0]:
                    findings.append(f"{where}: dead anchor '{target}' (no heading slugs to '#{frag}')")
    return findings


def run_snippets(readme: Path, root: Path) -> list[str]:
    """Execute every fenced python block in ``readme``; returns findings."""
    _, _, snippets = parse_markdown(readme)
    findings: list[str] = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(root / "src"), env.get("PYTHONPATH", "")) if p
    )
    for lineno, code in snippets:
        where = f"{readme.relative_to(root)}:{lineno}"
        print(f"[check_docs] executing python block at {where} ...", flush=True)
        proc = subprocess.run(
            [sys.executable, "-c", code], cwd=root, env=env,
            capture_output=True, text=True, timeout=600,
        )
        if proc.returncode != 0:
            tail = (proc.stderr or proc.stdout).strip().splitlines()[-8:]
            findings.append(f"{where}: snippet exited {proc.returncode}:\n    " + "\n    ".join(tail))
    return findings


def doc_files(root: Path) -> list[Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md")) if (root / "docs").is_dir() else []
    return [f for f in files if f.exists()]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path, default=Path(__file__).resolve().parent.parent,
                    help="repo root (default: the checkout containing this tool)")
    ap.add_argument("--no-exec", action="store_true", help="skip README snippet execution")
    args = ap.parse_args(argv)
    root = args.root.resolve()

    files = doc_files(root)
    findings = check_links(files, root)
    if not args.no_exec and (root / "README.md").exists():
        findings += run_snippets(root / "README.md", root)

    for f in findings:
        print(f"[check_docs] FAIL {f}")
    n_links = sum(len(parse_markdown(f)[1]) for f in files)
    print(f"[check_docs] {len(files)} files, {n_links} links checked: "
          f"{'clean' if not findings else f'{len(findings)} finding(s)'}")
    return len(findings)


if __name__ == "__main__":
    sys.exit(main())
